"""Training driver: data -> jitted train_step -> checkpoint/restart.

Fault tolerance: checkpoints are atomic + keep-k; `run()` resumes from
the latest checkpoint (params, opt state, step) and the stateless data
pipeline replays the exact batch sequence, so an interrupted run and an
uninterrupted run produce bitwise-identical parameters (tested).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import build_placement
from repro.data.pipeline import DataConfig, make_dataset
from repro.launch.steps import StepConfig, make_train_step
from repro.models import lm as LM
from repro.training import checkpoint as CKPT
from repro.training.optimizer import adamw_init
from repro.sharding.policy import Dist


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    seed: int = 0


def train(cfg: ModelConfig, dist: Dist, data_cfg: DataConfig,
          tc: TrainConfig, sc: Optional[StepConfig] = None,
          hooks: Optional[dict[int, Callable]] = None,
          verbose: bool = True):
    """Returns (params, opt_state, history). Resumes if checkpoints
    exist. ``hooks[step]`` runs before that step (failure injection in
    tests)."""
    sc = sc or StepConfig(cfg=cfg, dist=dist, remat=False, fsdp=False)
    placement = (build_placement(cfg.num_experts, dist.ep_size,
                                 dist.slots_per_device)
                 if cfg.is_moe else None)
    re_ = placement.replica_expert if placement else None
    key = jax.random.PRNGKey(tc.seed)
    params = LM.init_lm(cfg, key, dist, replica_expert=re_)
    opt_state = adamw_init(params, sc.opt)
    routing = (LM.build_lm_routing(cfg, placement) if cfg.is_moe else {})

    start = 0
    last = CKPT.latest_step(tc.ckpt_dir)
    if last is not None:
        (params, opt_state), meta = CKPT.restore(
            tc.ckpt_dir, (params, opt_state))
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        start = meta["step"]
        if verbose:
            print(f"[train] resumed from step {start}")

    step_fn = jax.jit(make_train_step(sc), donate_argnums=(0, 1))
    ds = make_dataset(data_cfg)
    history = []
    for step in range(start, tc.total_steps):
        if hooks and step in hooks:
            hooks[step](step, params, opt_state)
        batch = {k: jnp.asarray(v) for k, v in ds(step).items()}
        t0 = time.perf_counter()
        params, opt_state, loss, stats = step_fn(
            params, opt_state, batch, routing)
        loss = float(loss)
        dt = time.perf_counter() - t0
        history.append({"step": step, "loss": loss, "sec": dt})
        if verbose and (step % tc.log_every == 0
                        or step == tc.total_steps - 1):
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms)")
        if (step + 1) % tc.ckpt_every == 0 or step == tc.total_steps - 1:
            CKPT.save(tc.ckpt_dir, step + 1, (params, opt_state),
                      keep=tc.keep)
    return params, opt_state, history
