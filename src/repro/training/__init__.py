from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training import checkpoint

# NOTE: train_loop imports launch.steps which imports this package —
# import repro.training.train_loop directly to avoid the cycle.
__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "checkpoint"]
