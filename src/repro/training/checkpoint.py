"""Fault-tolerant checkpointing: atomic, keep-k, elastic restore.

Layout: <dir>/step_<n>/arrays.npz + meta.json, written to a tmp dir and
atomically renamed (a crash mid-write never corrupts the latest good
checkpoint).  ``restore`` optionally re-shards onto a different mesh
(elastic scaling: save on 2x16x16, resume on 16x16 or on 1 CPU device).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bfloat16 & friends: store bit-identical uint
# views and record the true dtype in meta.json
_EXTENDED = {np.dtype(ml_dtypes.bfloat16): np.uint16,
             np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
             np.dtype(ml_dtypes.float8_e5m2): np.uint8}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    dt = arr.dtype
    if dt in _EXTENDED:
        return arr.view(_EXTENDED[dt]), str(dt)
    return arr, str(dt)


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    for ext in _EXTENDED:
        if dtype_name == str(ext):
            return arr.view(ext)
    return arr


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str | Path, step: int, tree, *, keep: int = 3,
         extra_meta: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays = _flatten(tree)
    encoded, dtypes = {}, {}
    for k, v in arrays.items():
        encoded[k], dtypes[k] = _encode(v)
    np.savez(tmp / "arrays.npz", **encoded)
    meta = {"step": step, "time": time.time(),
            "keys": sorted(arrays.keys()),
            "dtypes": dtypes,
            **(extra_meta or {})}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic on same filesystem
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1])
                   for p in ckpt_dir.glob("step_*") if p.is_dir())
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, tree_like, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree of NamedSharding for elastic restore
    onto a (possibly different) mesh — leaves are device_put with the new
    sharding; None -> plain host arrays."""
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = ckpt_dir / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    meta_dtypes = json.loads((path / "meta.json").read_text()).get(
        "dtypes", {})
    flat_like = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else None)
    for i, (pth, leaf) in enumerate(flat_like[0]):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        arr = _decode(data[key], meta_dtypes.get(key, str(data[key].dtype)))
        assert arr.shape == tuple(leaf.shape), \
            f"{key}: ckpt {arr.shape} vs model {leaf.shape}"
        if shard_flat is not None and shard_flat[i] is not None:
            arr = jax.device_put(arr, shard_flat[i])
        leaves.append(arr)
    meta = json.loads((path / "meta.json").read_text())
    return jax.tree_util.tree_unflatten(flat_like[1], leaves), meta
