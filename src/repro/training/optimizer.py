"""AdamW (decoupled weight decay) on raw pytrees — no optax dependency.

Optimizer state shards exactly like the parameters (mu/nu mirror the
param pytree), so `param_pspecs` applies to it verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # bf16 moments halve optimizer HBM (standard at frontier scale;
    # master params stay fp32)
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jax.tree.map(
        lambda a: jnp.zeros(a.shape, mdt if a.dtype == jnp.float32
                            else a.dtype), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        mdt = m.dtype
        m = (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g)
        v = (cfg.b2 * v.astype(jnp.float32)
             + (1 - cfg.b2) * jnp.square(g))
        mh = m / (1 - cfg.b1 ** step)
        vh = v / (1 - cfg.b2 ** step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return p - lr * delta, m.astype(mdt), v.astype(mdt)

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
