"""Expert-parallel MoE FFN with METRO / EPLB token routing.

Datapath (paper §IV-C, Fig. 7), per MoE layer inside `shard_map`:

  1. all-gather token activations over the EP group (the paper's
     all-gather *dispatch*; replaces all-to-all).
  2. redundant router: logits -> top-k -> global histogram T[1..N]
     (identical on every EP rank — deterministic, so no routing-table
     exchange is ever needed; this is also the straggler story).
  3. routing: METRO greedy / EPLB round-robin -> replica slot per
     (token, k) pair.
  4. local grouped FFN over *activated local experts only* on the pairs
     whose slot is local (sorted, tile-padded buffer).
  5. combine: psum_scatter back over the EP axis (+ shared-expert
     contribution fused into the same collective).

Weight layout and parallelism (beyond-paper, required at TPU scale):
  physical expert weights are [R, d, n_up, fe] / [R, fe, d] with the
  slot dim R sharded over the EP axis ("model") and the expert-hidden
  dim fe sharded over the data axis (**intra-expert TP / ETP** — a
  604MB mixtral expert never fits a single v5e chip's share otherwise).

  * tokens mode (train/prefill): tokens stay within their data row;
    the body FSDP-gathers the fe shards over "data" per layer, then
    runs the paper's row-local EP datapath over "model".
  * features mode (decode): full-mesh EP x ETP — tokens are
    all-gathered over ("data","model") (decode batches are tiny: this
    is latency-dominated exactly as the paper argues for all-gather
    dispatch), each chip computes its (slot-column, fe-row) shard with
    **zero expert-weight movement** — the memory-bound regime keeps
    weights pinned — and one psum_scatter over the full mesh combines.
Local (mesh-less) mode emulates a virtual EP group for CPU tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import routing as core_routing
from repro.core.types import Placement
from repro.sharding.policy import Dist

# jax.shard_map became a top-level API only recently; older releases
# keep it in jax.experimental with `check_rep` instead of `check_vma`
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)

_INT = jnp.int32


# ----------------------------------------------------------------------
# params & routing tables
# ----------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key, dist: Dist, replica_expert: np.ndarray,
             dtype=jnp.float32):
    """Physical expert weights, slot-major ([R, ...], sharded on R over
    the EP axis; fe sharded over the data axis)."""
    d, fe = cfg.d_model, cfg.expert_hidden
    n = cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(fe)
    n_up = 2 if cfg.gated_mlp else 1
    # logical init then physical gather so replicas start identical
    w_up_l = jax.random.normal(k1, (n, d, n_up, fe), dtype) * s_in
    w_down_l = jax.random.normal(k2, (n, fe, d), dtype) * s_out
    idx = jnp.asarray(replica_expert)
    p = {
        "w_router": jax.random.normal(k3, (d, n), jnp.float32) * s_in,
        "w_up": w_up_l[idx],        # [R, d, n_up, fe]
        "w_down": w_down_l[idx],    # [R, fe, d]
    }
    if cfg.num_shared_experts:
        f_sh = cfg.num_shared_experts * fe
        k5, k6 = jax.random.split(k4)
        p["shared_up"] = jax.random.normal(
            k5, (d, n_up, f_sh), dtype) * s_in
        p["shared_down"] = jax.random.normal(k6, (f_sh, d), dtype) * s_out
    return p


def routing_tables(placement: Placement, table_width: Optional[int] = None):
    """Device-array routing tables for one MoE layer (step inputs)."""
    w = table_width or placement.max_replicas
    es = placement.expert_slots
    if es.shape[1] < w:
        es = np.pad(es, ((0, 0), (0, w - es.shape[1])), constant_values=-1)
    elif es.shape[1] > w:
        raise ValueError("table_width smaller than max replicas")
    return {
        "expert_slots": jnp.asarray(es, _INT),
        "num_replicas": jnp.asarray(placement.expert_num_replicas, _INT),
    }


# ----------------------------------------------------------------------
# router (top-k gating)
# ----------------------------------------------------------------------


def gating(cfg: ModelConfig, w_router, x):
    """x: [T, d] -> (expert_ids [T,k], gates [T,k] f32, probs [T,N] f32)."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    k = cfg.num_experts_per_tok
    if cfg.norm_topk_prob:
        vals, ids = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(vals, axis=-1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, k)
    return ids.astype(_INT), gates, jax.nn.softmax(logits, axis=-1)


def load_balance_loss(probs, ids, num_experts: int):
    """Switch-transformer auxiliary loss: N * sum_i f_i * p_i."""
    t = ids.shape[0] * ids.shape[1]
    f = core_routing.topk_histogram(ids, num_experts).astype(jnp.float32) / t
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


# ----------------------------------------------------------------------
# sorted, tile-padded pair buffer
# ----------------------------------------------------------------------


def build_pair_buffer(slots, lo, s_loc: int, capacity: int, tile: int):
    """Pack (token,k) pairs whose slot is in [lo, lo+s_loc) into a sorted,
    tile-aligned buffer.

    slots: [T, k] physical slot per pair (-1 pad). Returns:
      buf_pair:   [C] flat pair index per buffer row (-1 = padding row)
      group_pad:  [S_loc] tile-padded group sizes (sum <= C)
      tile_group: [C // tile] local-slot id per tile (for weight
                  streaming); **-1 marks dead tiles** — tiles with zero
                  live rows (the region past the last live group, and
                  any tile whose rows were all dropped by capacity).
                  Dead tiles are always *trailing* (live rows fill each
                  group's segment from the front, segments are packed
                  in slot order), which is what lets the kernels park
                  their DMA indices on the last live tile.
      n_live:     [] int32 count of live tiles (scalar-prefetch operand
                  for the Pallas kernels and the DMA accounting in
                  sim/roofline).
    Rows beyond a group's true size (padding) and rows dropped by
    capacity are marked -1.  The dead-tile contract every grouped-
    matmul impl honors: dead tiles cost no weight DMA and no FLOPs and
    their output rows are exact zeros.
    """
    t, k = slots.shape
    flat = slots.reshape(-1)
    npairs = t * k
    ls = flat - lo
    local = (ls >= 0) & (ls < s_loc)
    key = jnp.where(local, ls, s_loc)                 # invalid sorted last
    order = jnp.argsort(key, stable=True)
    key_sorted = key[order]
    # true group sizes + tile padding
    gs = jnp.zeros(s_loc, _INT).at[jnp.where(local, ls, 0)].add(
        local.astype(_INT))
    group_pad = ((gs + tile - 1) // tile) * tile
    pad_off = jnp.concatenate(
        [jnp.zeros(1, _INT), jnp.cumsum(group_pad)[:-1].astype(_INT)])
    # rank within group (over the sorted ordering)
    seg_start = jnp.searchsorted(key_sorted, key_sorted, side="left")
    rank = jnp.arange(npairs, dtype=_INT) - seg_start.astype(_INT)
    valid_sorted = key_sorted < s_loc
    dest = jnp.where(
        valid_sorted,
        pad_off[jnp.minimum(key_sorted, s_loc - 1)] + rank,
        capacity)                                      # OOB -> dropped
    buf_pair = jnp.full(capacity, -1, _INT).at[dest].set(
        order.astype(_INT), mode="drop")
    n_tiles = capacity // tile
    tile_start = jnp.arange(n_tiles, dtype=_INT) * tile
    bounds = jnp.cumsum(group_pad)
    tile_group = jnp.searchsorted(bounds, tile_start, side="right").astype(_INT)
    tile_group = jnp.minimum(tile_group, s_loc - 1)
    tile_live = jnp.any((buf_pair >= 0).reshape(n_tiles, tile), axis=1)
    tile_group = jnp.where(tile_live, tile_group, -1)
    n_live = jnp.sum(tile_live).astype(_INT)
    return buf_pair, group_pad, tile_group, n_live


# ----------------------------------------------------------------------
# grouped matmul implementations
# ----------------------------------------------------------------------


def grouped_matmul(x, w, group_pad, tile_group, impl: str):
    """x: [C, d] tile-aligned sorted buffer; w: [S_loc, d, f].

    Rows within group_pad ranges use that group's weights.  Dead tiles
    (``tile_group == -1``: rows past the last live group, including the
    residual capacity slack) take the dead-tile path — no weight
    streaming, no FLOPs where the impl can express it, exact-zero
    output rows.  Live tiles' intra-group pad rows still compute
    garbage the caller masks (they share a tile with real rows).
    """
    c, d = x.shape
    s_loc, _, f = w.shape
    if impl == "ragged":
        # segment g occupies [pad_off[g], pad_off[g] + group_pad[g])
        # clipped to the buffer; rows beyond the last clipped segment
        # (residual capacity slack) belong to NO group, and ragged_dot
        # zero-fills them — the dead-tile path.  (The seed impl dumped
        # that residual into the last local expert via
        # ``group_pad.at[s_loc-1].add(c - sum)``, making it stream the
        # last expert's weights over pure padding.)
        pad_off = jnp.concatenate(
            [jnp.zeros(1, _INT), jnp.cumsum(group_pad)[:-1].astype(_INT)])
        gs = jnp.clip(c - pad_off, 0, group_pad)
        out = jax.lax.ragged_dot(x, w, gs.astype(jnp.int32))
        # rows past the last segment belong to no group; ragged_dot
        # zero-fills them on XLA:CPU but that is not a documented
        # contract — mask explicitly so the exact-zero dead-tile
        # guarantee holds on every backend
        residual = jnp.arange(c) >= jnp.sum(gs)
        return jnp.where(residual[:, None], 0.0, out)
    if impl == "scan_tiles":
        tile = c // tile_group.shape[0]
        xt = x.reshape(-1, tile, d)

        def body(_, args):
            xi, g = args
            # lax.cond: dead tiles skip the matmul entirely
            yi = jax.lax.cond(
                g >= 0,
                lambda: xi @ w[jnp.maximum(g, 0)],
                lambda: jnp.zeros((tile, f), x.dtype))
            return None, yi

        _, yt = jax.lax.scan(body, None, (xt, tile_group))
        return yt.reshape(c, f)
    if impl == "onehot":  # oracle; O(C * S_loc * d * f)
        tile = c // tile_group.shape[0]
        row_group = jnp.repeat(tile_group, tile)
        # one_hot(-1) is the all-zero row: dead tiles select no expert
        sel = jax.nn.one_hot(row_group, s_loc, dtype=x.dtype)
        return jnp.einsum("cs,cd,sdf->cf", sel, x, w)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.grouped_ffn_matmul(x, w, tile_group)
    if impl == "fused":
        raise ValueError(
            "impl='fused' is the one-pass up→act→down megakernel — it "
            "has no single-matmul form; _expert_compute dispatches it")
    raise ValueError(f"unknown grouped_matmul impl {impl!r}")


# ----------------------------------------------------------------------
# local expert compute (runs identically in EP-shard and virtual mode)
# ----------------------------------------------------------------------


def _expert_compute(cfg: ModelConfig, w_up, w_down, x, ids, gates, slots,
                    *, lo, s_loc: int, capacity: int, tile: int,
                    impl: str):
    """Grouped FFN over local slots; returns partial output [T, d] f32.

    w_up: [S_loc, d, n_up, fe_shard]; w_down: [S_loc, fe_shard, d] —
    fe_shard may be a proper shard (ETP); the caller psums over the ETP
    axis.

    ``impl="fused"`` collapses the two grouped matmuls + gating into
    ONE Pallas megakernel call (kernels/moe_ffn.fused_expert_ffn_pallas):
    the ``[C, n_up*fe]`` hidden never materializes in HBM and each
    activated expert's weights stream exactly once per resident token
    tile (no ``moe_h`` remat point exists on this path — there is no
    hidden to save)."""
    t, d = x.shape
    k = ids.shape[-1]
    s_l, _, n_up, fe = w_up.shape
    buf_pair, group_pad, tile_group, _n_live = build_pair_buffer(
        slots, lo, s_loc, capacity, tile)
    row_valid = buf_pair >= 0
    tok = jnp.where(row_valid, buf_pair // k, 0)
    xg = jnp.where(row_valid[:, None], x[tok], 0).astype(x.dtype)

    if impl == "fused":
        from repro.kernels import ops as kops
        y = kops.fused_expert_ffn(
            xg, w_up.reshape(s_l, d, n_up * fe).astype(x.dtype),
            w_down.astype(x.dtype), tile_group, gated=cfg.gated_mlp)
        y = jax.ad_checkpoint.checkpoint_name(y, "moe_y")
    elif impl == "fused_paged":
        # the double-buffered paged megakernel, driven here with the
        # identity slot->frame map (all local slots resident in order);
        # the expert-pool bench exercises permuted maps directly
        from repro.kernels import ops as kops
        y = kops.fused_expert_ffn_paged(
            xg, w_up.reshape(s_l, d, n_up * fe).astype(x.dtype),
            w_down.astype(x.dtype), jnp.arange(s_l, dtype=jnp.int32),
            tile_group, gated=cfg.gated_mlp)
        y = jax.ad_checkpoint.checkpoint_name(y, "moe_y")
    else:
        h = grouped_matmul(
            xg, w_up.reshape(s_l, d, n_up * fe).astype(x.dtype),
            group_pad, tile_group, impl)
        if cfg.gated_mlp:
            g, u = jnp.split(h, 2, axis=-1)
            h = jax.nn.silu(g) * u
        else:
            h = jax.nn.gelu(h)
        # named for the save_moe remat policy: saving just these two
        # grouped matmuls avoids recomputing the dominant expert FLOPs
        # in backward while attention still remats (perf iteration,
        # EXPERIMENTS.md §Perf)
        h = jax.ad_checkpoint.checkpoint_name(h, "moe_h")
        y = grouped_matmul(h.astype(x.dtype), w_down.astype(x.dtype),
                           group_pad, tile_group, impl)
        y = jax.ad_checkpoint.checkpoint_name(y, "moe_y")

    gate = jnp.where(row_valid, gates.reshape(-1)[jnp.maximum(buf_pair, 0)], 0.0)
    y = y.astype(jnp.float32) * gate[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[tok].add(
        jnp.where(row_valid[:, None], y, 0.0))
    return out


def _shared_expert(cfg: ModelConfig, params, x):
    """Always-active shared experts on (possibly ETP-sharded) weights;
    the partial contribution joins the MoE combine psum for free."""
    up, down = params["shared_up"], params["shared_down"]
    d, n_up, f_sh = up.shape
    h = x @ up.reshape(d, n_up * f_sh).astype(x.dtype)
    if cfg.gated_mlp:
        a, b = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(a) * b
    else:
        h = jax.nn.gelu(h)
    return (h @ down.astype(x.dtype)).astype(jnp.float32)


# ----------------------------------------------------------------------
# the MoE layer
# ----------------------------------------------------------------------


def _moe_inner(cfg: ModelConfig, params, tables, x, *, algo, lo, s_loc,
               capacity, tile, impl, ep_size, slots_per_device,
               use_pallas_route=False, with_stats=True, row_valid=None):
    """Router + routing + local grouped FFN. x: [T, d] (full EP-group
    tokens). Returns (partial_out [T, d] f32, stats).

    ``row_valid`` [T] masks padding rows out of routing entirely: their
    top-k choices become -1 pads, so they never skew the histogram,
    EPLB round-robin ranks, METRO's activation decisions, or the
    expert-load stats that drive rebalancing — and routing becomes
    bitwise-invariant to how much a serving batch was padded."""
    ids, gates, probs = gating(cfg, params["w_router"], x)
    if row_valid is not None:
        ids = jnp.where(row_valid[:, None], ids, -1)
    hist = core_routing.topk_histogram(ids, cfg.num_experts)
    slots = core_routing.route(
        algo, ids, hist, tables["expert_slots"], tables["num_replicas"],
        num_devices=ep_size, slots_per_device=slots_per_device,
        use_pallas=use_pallas_route)
    out = _expert_compute(
        cfg, params["w_up"], params["w_down"], x, ids, gates, slots,
        lo=lo, s_loc=s_loc, capacity=capacity, tile=tile, impl=impl)
    if cfg.num_shared_experts:
        out = out + _shared_expert(cfg, params, x)
    from repro.core import metrics as m
    act = m.activated_per_device(slots, ep_size, slots_per_device)
    stats = {
        "aux_loss": load_balance_loss(probs, ids, cfg.num_experts),
        "max_activated": jnp.max(act).astype(jnp.float32),
        "mean_activated": jnp.mean(act.astype(jnp.float32)),
        "max_tokens": jnp.max(
            m.tokens_per_device(slots, ep_size, slots_per_device)
        ).astype(jnp.float32),
        # per-expert token loads (drives EPLB rebalancing in the engine)
        "expert_hist": hist.astype(jnp.float32),
        # per-physical-slot activation (drives expert-weight paging:
        # METRO and EPLB pick the same logical experts but different
        # replica slots, and slots are what the pool pages)
        "slot_hist": _slot_histogram(slots, ep_size * slots_per_device),
    }
    return out, stats


def _slot_histogram(slots, n_slots: int):
    """[T, k] global physical-slot choices (-1 pads) -> [n_slots] f32
    activation counts.  Deterministically identical on every rank
    (redundant routing), like ``expert_hist``."""
    valid = slots >= 0
    return jnp.zeros((n_slots,), jnp.float32).at[
        jnp.where(valid, slots, 0)].add(valid.astype(jnp.float32))


def _capacity(t_group: int, k: int, *, algo: str, mode: str, ep: int,
              s_loc: int, tile: int, capacity_factor: float) -> int:
    pairs = t_group * k
    if algo == "metro" or mode in ("features", "local") or ep == 1:
        c = pairs                         # no-drop: worst case all local
    else:
        c = int(np.ceil(pairs * capacity_factor / ep))
    c = c + s_loc * (tile - 1)            # tile-padding slack
    return int(np.ceil(max(c, tile) / tile)) * tile


def moe_ffn(cfg: ModelConfig, dist: Dist, params, tables, x, *,
            algo: str = "eplb", capacity_factor: float = 1.25,
            impl: str = "ragged", tile: int = 8, mode: str = "tokens",
            use_pallas_route: bool = False, row_valid=None):
    """MoE FFN over x: [B, S, d] (tokens mode) or [T, d] (features mode).

    tokens mode: x sequence-sharded over EP axis -> paper's all-gather
    dispatch on tokens (per data row; fe shards FSDP-gathered per layer).
    features mode (decode): full-mesh EP x ETP, weights never move.
    Virtual-EP local fallback when no mesh is active.

    ``row_valid`` (bool, x's token shape — [B, S] or [T]) excludes
    padding tokens from routing (see :func:`_moe_inner`).
    """
    squeeze = x.ndim == 3
    d = x.shape[-1]
    ep, spd = dist.ep_size, dist.slots_per_device
    k = cfg.num_experts_per_tok

    if dist.mesh is None or dist.tp_axis is None:
        # virtual EP: all slots local, same math, no collectives
        x2 = x.reshape(-1, d) if squeeze else x
        rv = row_valid.reshape(-1) if row_valid is not None else None
        capacity = _capacity(x2.shape[0], k, algo=algo, mode="local", ep=ep,
                             s_loc=ep * spd, tile=tile,
                             capacity_factor=capacity_factor)
        out, stats = _moe_inner(
            cfg, params, tables, x2, algo=algo, lo=0, s_loc=ep * spd,
            capacity=capacity, tile=tile, impl=impl, ep_size=ep,
            slots_per_device=spd, use_pallas_route=use_pallas_route,
            row_valid=rv)
        out = out.astype(x.dtype)
        return (out.reshape(x.shape) if squeeze else out), stats

    mesh, ax = dist.mesh, dist.tp_axis
    from jax.sharding import PartitionSpec as P
    dp = dist.dp_axes
    all_axes = tuple(mesh.axis_names)
    # the ETP axis: fe sharding over the in-pod data axis
    etp = "data" if "data" in mesh.axis_names else None
    etp_size = mesh.shape[etp] if etp else 1

    def _reduce_stats(stats, axes):
        return {
            "aux_loss": jax.lax.pmean(stats["aux_loss"], axes),
            "max_activated": jax.lax.pmax(stats["max_activated"], axes),
            "mean_activated": jax.lax.pmean(stats["mean_activated"], axes),
            "max_tokens": jax.lax.pmax(stats["max_tokens"], axes),
            # identical within an EP group; distinct across data rows
            "expert_hist": jax.lax.psum(stats["expert_hist"], axes) / ep,
            "slot_hist": jax.lax.psum(stats["slot_hist"], axes) / ep,
        }

    has_shared = bool(cfg.num_shared_experts)
    shared = ((params["shared_up"], params["shared_down"])
              if has_shared else None)

    # weight specs: slots over EP axis, fe over ETP axis
    fe = cfg.expert_hidden
    etp_w = etp if (etp and fe % etp_size == 0) else None
    wup_spec = P(ax, None, None, etp_w)
    wdn_spec = P(ax, etp_w, None)
    f_sh = cfg.num_shared_experts * fe
    sh_ok = etp and f_sh % (etp_size * ep) == 0
    shup_spec = P(None, None, (etp, ax) if sh_ok else ax)
    shdn_spec = P((etp, ax) if sh_ok else ax, None)
    shared_spec = (shup_spec, shdn_spec) if has_shared else None

    if mode == "tokens":
        b, s, _ = x.shape
        rv_full = (row_valid if row_valid is not None
                   else jnp.ones((b, s), bool))
        # sequence sharded over EP axis when divisible (paper's SP
        # dispatch); otherwise x enters replicated and gather is a no-op.
        gather = s % ep == 0
        dp_ok = b % dist.dp_size == 0
        b_l = b // dist.dp_size if dp_ok else b
        t_group = b_l * s
        capacity = _capacity(t_group, k, algo=algo, mode="tokens", ep=ep,
                             s_loc=spd, tile=tile,
                             capacity_factor=capacity_factor)
        x_spec = P(dp if dp_ok else None, ax if gather else None, None)
        rv_spec = P(dp if dp_ok else None, ax if gather else None)

        def body(xb, rvb, w_up, w_down, w_router, shared, es, nr):
            rank = jax.lax.axis_index(ax)
            # FSDP-gather the fe shards within the data row (cast to the
            # compute dtype first: halves the gather traffic)
            w_up = w_up.astype(xb.dtype)
            w_down = w_down.astype(xb.dtype)
            if etp_w:
                w_up = jax.lax.all_gather(w_up, etp_w, axis=3, tiled=True)
                w_down = jax.lax.all_gather(w_down, etp_w, axis=1,
                                            tiled=True)
            xg = (jax.lax.all_gather(xb, ax, axis=1, tiled=True)
                  if gather else xb)
            rvg = (jax.lax.all_gather(rvb, ax, axis=1, tiled=True)
                   if gather else rvb)
            bl = xg.shape[0]
            x2 = xg.reshape(-1, d)
            p = {"w_router": w_router, "w_up": w_up, "w_down": w_down}
            if shared is not None:
                su, sd = shared
                if sh_ok:  # gather the data-axis part; keep EP shard
                    su = jax.lax.all_gather(su, etp, axis=2, tiled=True)
                    sd = jax.lax.all_gather(sd, etp, axis=0, tiled=True)
                p["shared_up"], p["shared_down"] = su, sd
            out, stats = _moe_inner(
                cfg, p, {"expert_slots": es, "num_replicas": nr}, x2,
                algo=algo, lo=rank * spd, s_loc=spd, capacity=capacity,
                tile=tile, impl=impl, ep_size=ep, slots_per_device=spd,
                use_pallas_route=use_pallas_route,
                row_valid=rvg.reshape(-1))
            out = out.astype(xb.dtype).reshape(bl, -1, d)
            if gather:
                out = jax.lax.psum_scatter(out, ax, scatter_dimension=1,
                                           tiled=True)
            else:
                out = jax.lax.psum(out, ax)
            return out, _reduce_stats(stats, all_axes)

        out, stats = _shard_map(
            body, mesh=mesh,
            in_specs=(x_spec, rv_spec, wup_spec, wdn_spec, P(),
                      shared_spec, P(), P()),
            out_specs=(x_spec, P()),
            check_vma=False,
        )(x, rv_full, params["w_up"], params["w_down"],
          params["w_router"], shared,
          tables["expert_slots"], tables["num_replicas"])
        return out, stats

    # ------------------------------------------------------------------
    # features mode (decode): full-mesh EP x ETP
    #   x: [T, d], d sharded over every in-pod axis; tokens replicated
    #   over "pod"... sharded over pod when divisible.
    # ------------------------------------------------------------------
    t = x.shape[0]
    rv_full = row_valid if row_valid is not None else jnp.ones((t,), bool)
    pod = tuple(a for a in dp if a != etp)         # ("pod",) or ()
    pod_size = int(np.prod([mesh.shape[a] for a in pod])) if pod else 1
    pod_ok = pod and t % pod_size == 0
    t_group = t // pod_size if pod_ok else t
    capacity = _capacity(t_group, k, algo=algo, mode="features", ep=ep,
                         s_loc=spd, tile=tile,
                         capacity_factor=capacity_factor)
    gather_axes = tuple(a for a in (etp, ax) if a)
    gx = int(np.prod([mesh.shape[a] for a in gather_axes]))
    gather = d % gx == 0
    x_spec = P(pod if pod_ok else None, gather_axes if gather else None)
    rv_spec = P(pod if pod_ok else None)

    def body_f(xb, rvb, w_up, w_down, w_router, shared, es, nr):
        rank = jax.lax.axis_index(ax)
        xg = (jax.lax.all_gather(xb, gather_axes, axis=1, tiled=True)
              if gather else xb)
        p = {"w_router": w_router, "w_up": w_up, "w_down": w_down}
        if shared is not None:
            p["shared_up"], p["shared_down"] = shared
        out, stats = _moe_inner(
            cfg, p, {"expert_slots": es, "num_replicas": nr}, xg,
            algo=algo, lo=rank * spd, s_loc=spd, capacity=capacity,
            tile=tile, impl=impl, ep_size=ep, slots_per_device=spd,
            use_pallas_route=use_pallas_route, row_valid=rvb)
        # combine over slots (EP axis) AND fe shards (ETP axis) in one
        # collective; weights never moved.
        if gather:
            out = jax.lax.psum_scatter(out, gather_axes,
                                       scatter_dimension=1, tiled=True)
        else:
            out = jax.lax.psum(out, gather_axes)
        return out.astype(xb.dtype), _reduce_stats(stats, all_axes)

    out, stats = _shard_map(
        body_f, mesh=mesh,
        in_specs=(x_spec, rv_spec, wup_spec, wdn_spec, P(), shared_spec,
                  P(), P()),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, rv_full, params["w_up"], params["w_down"], params["w_router"],
      shared, tables["expert_slots"], tables["num_replicas"])
    return out, stats
