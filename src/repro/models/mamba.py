"""Mamba-1 selective-SSM block (falcon-mamba, jamba's SSM layers).

TPU adaptation: the CUDA selective-scan kernel becomes a *chunked
associative scan* — `lax.associative_scan` inside sequence chunks with a
`lax.scan` carrying the recurrent state across chunks, so peak memory is
O(B * chunk * d_inner * N) instead of O(B * S * d_inner * N).  Channels
(d_inner) are independent, so TP shards d_inner cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def dt_rank(cfg: ModelConfig) -> int:
    return max(cfg.d_model // 16, 1)


def init_mamba(cfg: ModelConfig, key):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    r = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    s = 1.0 / np.sqrt(d)
    p = {
        "w_in": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
        * (1.0 / np.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "w_x": jax.random.normal(ks[2], (di, r + 2 * n), jnp.float32)
        * (1.0 / np.sqrt(di)),
        "w_dt": jax.random.normal(ks[3], (r, di), jnp.float32)
        * (1.0 / np.sqrt(r)),
        "dt_bias": jnp.full((di,), np.log(np.expm1(0.01)), jnp.float32),
        # S4D-real init: A = -(1..N) per channel
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": jax.random.normal(ks[4], (di, d), jnp.float32)
        * (1.0 / np.sqrt(di)),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv, width K, via K shifted adds.
    x: [B, S, di]; w: [K, di]."""
    k = w.shape[0]
    y = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        y = y + shifted * w[k - 1 - i]
    return y + b


def _ssm_params(cfg, params, xm):
    """xm: [B, S, di] -> (dt [B,S,di], B_t [B,S,N], C_t [B,S,N])."""
    r, n = dt_rank(cfg), cfg.ssm_state
    proj = xm @ params["w_x"].astype(xm.dtype)
    dtp, bt, ct = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dtp.astype(jnp.float32) @ params["w_dt"] + params["dt_bias"])
    return dt, bt.astype(jnp.float32), ct.astype(jnp.float32)


def mamba_train(cfg: ModelConfig, params, x, *, chunk: int = 128,
                dist=None, return_state: bool = False, lengths=None):
    """Full-sequence mamba block. x: [B, S, d] -> ([B, S, d], state).

    state (when return_state, for prefill cache handoff) is the decode
    cache: {"conv": last K-1 pre-conv inputs, "h": final SSM state}.

    ``lengths`` [B] (with return_state) makes the handoff per-row: the
    recurrence has no position mask, so on a length-padded batch the
    *final* state has absorbed the padding tokens — here the state is
    instead read at each row's true last position and the conv window
    is the K-1 real inputs before it, exactly what step-by-step decode
    would have produced."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    xz = x @ params["w_in"].astype(x.dtype)
    xm_raw, z = jnp.split(xz, 2, axis=-1)
    if dist is not None:
        xm_raw = dist.shard(xm_raw, dist.dp_axes, None, dist.tp_axis)
        z = dist.shard(z, dist.dp_axes, None, dist.tp_axis)
    xm = jax.nn.silu(_causal_conv(xm_raw, params["conv_w"].astype(x.dtype),
                                  params["conv_b"].astype(x.dtype)))
    dt, bt, ct = _ssm_params(cfg, params, xm)
    a = -jnp.exp(params["A_log"])                     # [di, N]

    # per-step decay/input in log space:
    #   h_t = exp(dt_t * A) h_{t-1} + (dt_t * x_t) B_t
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:  # causal: trailing zero-pad never affects earlier outputs
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bt = jnp.pad(bt, ((0, 0), (0, pad), (0, 0)))
        ct = jnp.pad(ct, ((0, 0), (0, pad), (0, 0)))
        xm = jnp.pad(xm, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    n_chunks = s_pad // chunk
    xf = xm.astype(jnp.float32)

    collect = return_state and lengths is not None

    def chunk_body(h, idx):
        sl = lambda v: jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, 1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(bt), sl(ct), sl(xf)
        decay = jnp.exp(dt_c[..., None] * a)                    # [B,c,di,N]
        inp = (dt_c * x_c)[..., None] * b_c[:, :, None, :]      # [B,c,di,N]

        def combine(e1, e2):
            a1, u1 = e1
            a2, u2 = e2
            return a1 * a2, u1 * a2 + u2

        acc_a, acc_u = jax.lax.associative_scan(
            combine, (decay, inp), axis=1)
        h_t = acc_a * h[:, None] + acc_u                        # [B,c,di,N]
        y_c = jnp.einsum("bcin,bcn->bci", h_t, c_c)
        return h_t[:, -1], (y_c, h_t if collect else h_t[:, :0])

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_final, (ys, hs) = jax.lax.scan(chunk_body, h0, jnp.arange(n_chunks))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s_pad, di)[:, :s]
    y = y + xf[:, :s] * params["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["w_out"].astype(x.dtype)
    if dist is not None:
        out = dist.shard(out, dist.dp_axes, None, None)
    if return_state:
        k = cfg.ssm_conv
        if lengths is None:
            conv_cache = jnp.pad(
                xm_raw, ((0, 0), (max(k - 1 - s, 0), 0), (0, 0)))[:, -(k - 1):]
            return out, {"conv": conv_cache, "h": h_final}
        # per-row handoff at position lengths[b]-1
        hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, di, n)
        idx = jnp.clip(lengths - 1, 0, s_pad - 1)
        h_state = jnp.take_along_axis(
            hs, idx[:, None, None, None], axis=1)[:, 0]
        h_state = jnp.where((lengths > 0)[:, None, None], h_state, 0.0)
        pos = lengths[:, None] - (k - 1) + jnp.arange(k - 1)[None, :]
        g = jnp.take_along_axis(
            xm_raw, jnp.clip(pos, 0, s - 1)[:, :, None], axis=1)
        conv_cache = jnp.where((pos >= 0)[:, :, None], g, 0)
        return out, {"conv": conv_cache, "h": h_state}
    return out, {}


def mamba_chunk(cfg: ModelConfig, params, x, cache, n_tok, *, dist=None):
    """Resumable prefill-chunk step: run ``n_tok`` tokens per row against
    the decode cache and hand the advanced cache back.

    x: [B, C, d]; cache: {"conv": [B, K-1, di] raw pre-conv inputs,
    "h": [B, di, N] f32}; n_tok: [B] valid tokens this chunk (<= C,
    positions beyond a row's n_tok are padding and leave its state
    untouched).  Returns ([B, C, d], new_cache).

    Unlike :func:`mamba_train` (chunked *associative* scan, whose
    combine tree depends on the chunk size), the recurrence here is a
    strictly sequential per-token scan — the same arithmetic in the same
    order for every token no matter how the prompt is split — so chunked
    prefill is bitwise identical to a single monolithic chunk call, and
    the handed-off state is exactly what step-by-step decode would have
    produced."""
    b, c, d = x.shape
    k = cfg.ssm_conv
    xz = x @ params["w_in"].astype(x.dtype)
    xm_raw, z = jnp.split(xz, 2, axis=-1)
    if dist is not None:
        xm_raw = dist.shard(xm_raw, dist.dp_axes, None, dist.tp_axis)
        z = dist.shard(z, dist.dp_axes, None, dist.tp_axis)
    # conv over (cached K-1 raw inputs ++ this chunk); slicing off the
    # history rows reproduces _causal_conv's zero-padding bit-for-bit
    # when the cache is all-zeros (a fresh sequence).
    hist = jnp.concatenate(
        [cache["conv"].astype(xm_raw.dtype), xm_raw], axis=1)
    xm = jax.nn.silu(_causal_conv(
        hist, params["conv_w"].astype(x.dtype),
        params["conv_b"].astype(x.dtype))[:, k - 1:])
    dt, bt, ct = _ssm_params(cfg, params, xm)
    # padding tokens become exact no-ops: dt=0 -> decay=exp(0)=1, inp=0
    valid = jnp.arange(c)[None, :] < n_tok[:, None]
    dt = jnp.where(valid[..., None], dt, 0.0)
    a = -jnp.exp(params["A_log"])                     # [di, N]
    xf = xm.astype(jnp.float32)
    decay = jnp.exp(dt[..., None] * a)                # [B, C, di, N]
    inp = (dt * xf)[..., None] * bt[:, :, None, :]    # [B, C, di, N]

    def body(h, t):
        dec_t, inp_t, c_t = t
        h = h * dec_t + inp_t
        return h, jnp.einsum("bin,bn->bi", h, c_t)

    h_last, ys = jax.lax.scan(
        body, cache["h"],
        (decay.transpose(1, 0, 2, 3), inp.transpose(1, 0, 2, 3),
         ct.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + xf * params["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["w_out"].astype(x.dtype)
    if dist is not None:
        out = dist.shard(out, dist.dp_axes, None, None)
    # conv handoff: the K-1 raw inputs before each row's position n_tok
    # (in the concat frame that is exactly indices n_tok .. n_tok+K-2)
    idx = n_tok[:, None] + jnp.arange(k - 1)[None, :]
    new_conv = jnp.take_along_axis(hist, idx[:, :, None], axis=1)
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "h": h_last}


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(cfg: ModelConfig, params, x, cache, *, dist=None):
    """Single-token step. x: [B, 1, d]; cache: {conv, h}."""
    b, _, d = x.shape
    xz = x[:, 0] @ params["w_in"].astype(x.dtype)     # [B, 2di]
    xm, z = jnp.split(xz, 2, axis=-1)
    # conv over (cached K-1 inputs, current)
    hist = jnp.concatenate(
        [cache["conv"].astype(xm.dtype), xm[:, None]], axis=1)  # [B,K,di]
    w = params["conv_w"].astype(xm.dtype)
    xc = jnp.einsum("bki,ki->bi", hist, w) + params["conv_b"].astype(xm.dtype)
    xc = jax.nn.silu(xc)
    dt, bt, ct = _ssm_params(cfg, params, xc[:, None])
    dt, bt, ct = dt[:, 0], bt[:, 0], ct[:, 0]
    a = -jnp.exp(params["A_log"])
    xf = xc.astype(jnp.float32)
    decay = jnp.exp(dt[..., None] * a)                          # [B,di,N]
    h = cache["h"] * decay + (dt * xf)[..., None] * bt[:, None, :]
    y = jnp.einsum("bin,bn->bi", h, ct) + xf * params["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["w_out"].astype(x.dtype))[:, None]
    new_cache = {"conv": hist[:, 1:].astype(cache["conv"].dtype), "h": h}
    return out, new_cache
