"""Shared neural layers: norms, RoPE, MLP, attention (all variants).

Attention paths:
  * full/causal train+prefill — chunked online-softmax ("flash-style")
    scan over KV chunks; memory O(S * chunk) instead of O(S^2).
  * sliding-window train+prefill — banded: each Q chunk attends only to
    its own chunk + the preceding window (statically-sized slice), so
    compute is O(S * (W + chunk)), not O(S^2).
  * decode (q_len = 1) — dense scores against the KV cache (linear in
    cache length); SWA uses a rolling-buffer cache of width W.

GQA is computed in grouped form (no materialized head repetition).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    """Non-parametric when scale/bias are None (olmo)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def apply_norm(cfg: ModelConfig, params, x):
    if cfg.nonparametric_norm:
        return layer_norm(x, None, None)
    return rms_norm(x, params["scale"])


def init_norm(cfg: ModelConfig, key):
    if cfg.nonparametric_norm:
        return {}
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, head_dim]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                    # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------
# dense MLP (SwiGLU or GELU)
# ----------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(f)
    p = {"w_up": jax.random.normal(k2, (d, f), jnp.float32) * scale_in,
         "w_down": jax.random.normal(k3, (f, d), jnp.float32) * scale_out}
    if cfg.gated_mlp:
        p["w_gate"] = jax.random.normal(k1, (d, f), jnp.float32) * scale_in
    return p


def apply_mlp(cfg: ModelConfig, params, x, dist=None):
    h_up = x @ params["w_up"]
    if dist is not None:
        h_up = dist.shard(h_up, dist.dp_axes, None, dist.tp_axis)
    if cfg.gated_mlp:
        h = jax.nn.silu(x @ params["w_gate"]) * h_up
    else:
        h = jax.nn.gelu(h_up)
    y = h @ params["w_down"]
    if dist is not None:
        y = dist.shard(y, dist.dp_axes, None, None)
    return y


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    """Resolved attention head layout after TP-divisibility padding.

    If num_kv_heads doesn't divide the TP axis, KV heads are logically
    replicated to ``kv`` so the KV tensors shard (standard GQA-on-TP
    practice; noted in DESIGN.md).
    """
    heads: int
    kv: int
    head_dim: int

    @property
    def group(self) -> int:
        return self.heads // self.kv


def attn_dims(cfg: ModelConfig, tp: int = 1) -> AttnDims:
    # no KV-head padding: when kv doesn't divide the TP axis, the KV
    # *cache* shards its sequence dim instead (lm.cache_pspec), which
    # avoids doubling cache bytes for kv=8 archs on 16-way TP.
    h, kv = cfg.num_heads, cfg.num_kv_heads
    if kv <= 0:
        kv = h
    if kv and h % kv != 0:            # safety: fall back to MHA grouping
        kv = h
    return AttnDims(h, kv, cfg.head_dim)


def init_attention(cfg: ModelConfig, key, tp: int = 1):
    d = cfg.d_model
    dims = attn_dims(cfg, tp)
    kq, kk, kv_, ko, kn = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": jax.random.normal(kq, (d, dims.heads * dims.head_dim), jnp.float32) * s,
        "wk": jax.random.normal(kk, (d, dims.kv * dims.head_dim), jnp.float32) * s,
        "wv": jax.random.normal(kv_, (d, dims.kv * dims.head_dim), jnp.float32) * s,
        "wo": jax.random.normal(ko, (dims.heads * dims.head_dim, d), jnp.float32)
        * (1.0 / np.sqrt(dims.heads * dims.head_dim)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dims.head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((dims.head_dim,), jnp.float32)
    return p


def _project_qkv(cfg, params, x, positions, dims: AttnDims, *, rope=True):
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, dims.heads, dims.head_dim)
    k = (x @ params["wk"]).reshape(b, s, dims.kv, dims.head_dim)
    v = (x @ params["wv"]).reshape(b, s, dims.kv, dims.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if rope:
        q = apply_rope(q, positions[:, :, None], cfg.rope_theta)
        k = apply_rope(k, positions[:, :, None], cfg.rope_theta)
    # [B, kv, group|1, S, hd]
    q = q.reshape(b, s, dims.kv, dims.group, dims.head_dim).transpose(0, 2, 3, 1, 4)
    k = k.transpose(0, 2, 1, 3)[:, :, None]
    v = v.transpose(0, 2, 1, 3)[:, :, None]
    return q, k, v


def _flash_causal(q, k, v, *, chunk: int, window: Optional[int], scale):
    """Online-softmax attention over KV chunks.

    q: [B, KV, G, S, hd]; k/v: [B, KV, 1, S, hd].  For SWA (window W),
    each Q chunk attends to a statically-sized banded KV slice instead of
    scanning all chunks.
    """
    b, kvh, g, s_orig, hd = q.shape
    chunk = min(chunk, s_orig)
    pad = (-s_orig) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0),) * 3 + ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0),) * 3 + ((0, pad), (0, 0)))
    s = s_orig + pad
    n_q = s // chunk
    qs = q.reshape(b, kvh, g, n_q, chunk, hd)

    if window is not None and window < s:
        band = int(np.ceil(window / chunk)) * chunk  # look-back, full chunks
        kv_len = band + chunk

        def per_qchunk(qi, idx):
            # KV slice [idx*chunk - band, idx*chunk + chunk)
            start = idx * chunk
            k_sl = jax.lax.dynamic_slice_in_dim(
                jnp.pad(k, ((0, 0), (0, 0), (0, 0), (band, 0), (0, 0))),
                start, kv_len, axis=3)
            v_sl = jax.lax.dynamic_slice_in_dim(
                jnp.pad(v, ((0, 0), (0, 0), (0, 0), (band, 0), (0, 0))),
                start, kv_len, axis=3)
            qpos = start + jnp.arange(chunk)
            kpos = start - band + jnp.arange(kv_len)
            mask = (kpos[None, :] <= qpos[:, None]) & \
                   (kpos[None, :] > qpos[:, None] - window) & \
                   (kpos[None, :] >= 0)
            logits = jnp.einsum("bkgqh,bkgsh->bkgqs", qi, k_sl,
                                preferred_element_type=jnp.float32) * scale
            logits = jnp.where(mask[None, None, None], logits, _NEG)
            p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
            return jnp.einsum("bkgqs,bkgsh->bkgqh", p, v_sl)

        out = jax.lax.map(
            lambda t: per_qchunk(t[0], t[1]),
            (qs.transpose(3, 0, 1, 2, 4, 5), jnp.arange(n_q)))
        out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, kvh, g, s, hd)
        return out[:, :, :, :s_orig]

    # full causal: scan KV chunks with running (m, l, o)
    n_kv = s // chunk
    ks = k.reshape(b, kvh, 1, n_kv, chunk, hd)
    vs = v.reshape(b, kvh, 1, n_kv, chunk, hd)
    qpos = jnp.arange(s)

    def body(carry, kv_idx):
        m, l, o = carry
        kj = jax.lax.dynamic_index_in_dim(ks, kv_idx, axis=3, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vs, kv_idx, axis=3, keepdims=False)
        kpos = kv_idx * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bkgqh,bkgsh->bkgqs", q, kj,
                            preferred_element_type=jnp.float32) * scale
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None, None], logits, _NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bkgqs,bkgsh->bkgqh", p.astype(vj.dtype), vj).astype(jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, kvh, g, s), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    o0 = jnp.zeros((b, kvh, g, s, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), jnp.arange(n_kv))
    out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out[:, :, :, :s_orig]


def attention_train(cfg: ModelConfig, params, x, *, positions=None,
                    window: Optional[int] = None, dims: Optional[AttnDims] = None,
                    chunk: int = 1024, rope: bool = True, dist=None,
                    return_kv: bool = False):
    """Causal (optionally sliding-window) attention, train/prefill.

    Returns (out, kv) where kv = (k [B,KV,S,hd], v) when return_kv (for
    prefill cache fills) else None."""
    b, s, d = x.shape
    dims = dims or attn_dims(cfg)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(cfg, params, x, positions, dims, rope=rope)
    if dist is not None:
        q = dist.shard(q, dist.dp_axes, dist.tp_axis)
        k = dist.shard(k, dist.dp_axes, dist.tp_axis)
        v = dist.shard(v, dist.dp_axes, dist.tp_axis)
    scale = 1.0 / np.sqrt(dims.head_dim)
    o = _flash_causal(q, k, v, chunk=chunk, window=window, scale=scale)
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, s, dims.heads * dims.head_dim)
    out = o @ params["wo"]
    if return_kv:
        return out, (k[:, :, 0], v[:, :, 0])
    return out, None


def attention_bidir(cfg: ModelConfig, params, x, *, dims=None, dist=None):
    """Bidirectional attention (whisper encoder). Small S: dense scores."""
    b, s, d = x.shape
    dims = dims or attn_dims(cfg)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(cfg, params, x, positions, dims, rope=False)
    scale = 1.0 / np.sqrt(dims.head_dim)
    logits = jnp.einsum("bkgqh,bkgsh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bkgsh->bkgqh", p, v)
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, s, dims.heads * dims.head_dim)
    return o @ params["wo"]


def attention_cross(cfg: ModelConfig, params, x, kv_cache, *, dims=None):
    """Cross-attention against precomputed encoder K/V (whisper decoder).

    kv_cache: {"k": [B, KV, F, hd], "v": ...} (no RoPE on cross keys)."""
    b, s, d = x.shape
    dims = dims or attn_dims(cfg)
    q = (x @ params["wq"]).reshape(b, s, dims.heads, dims.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
    q = q.reshape(b, s, dims.kv, dims.group, dims.head_dim).transpose(0, 2, 3, 1, 4)
    k, v = kv_cache["k"][:, :, None], kv_cache["v"][:, :, None]
    scale = 1.0 / np.sqrt(dims.head_dim)
    logits = jnp.einsum("bkgqh,bkgsh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bkgsh->bkgqh", p, v)
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, s, dims.heads * dims.head_dim)
    return o @ params["wo"]


def cross_kv(cfg: ModelConfig, params, enc_out, *, dims=None):
    """Precompute cross-attention K/V from encoder output."""
    b, f, _ = enc_out.shape
    dims = dims or attn_dims(cfg)
    k = (enc_out @ params["wk"]).reshape(b, f, dims.kv, dims.head_dim)
    v = (enc_out @ params["wv"]).reshape(b, f, dims.kv, dims.head_dim)
    if cfg.qk_norm:
        k = rms_norm(k, params["k_norm"])
    return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}


# ---------------------------- decode ----------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int] = None, dtype=jnp.bfloat16,
                  tp: int = 1):
    dims = attn_dims(cfg, tp)
    n = min(window, max_len) if window else max_len
    shape = (batch, dims.kv, n, dims.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                        dtype=jnp.bfloat16, tp: int = 1):
    """Flat page pool shared by all sequences of one attention layer.

    Layout [num_pages, page_size, kv, head_dim]: (page, offset) flattens
    to one linear token index, so reads/writes are single gathers and
    scatters over a ``[num_pages * page_size, kv, hd]`` view."""
    dims = attn_dims(cfg, tp)
    shape = (num_pages, page_size, dims.kv, dims.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_prefill_paged(cfg: ModelConfig, params, x, cache, page_table,
                            start, n_tok, *, window: Optional[int] = None,
                            dims=None, rope: bool = True, dist=None):
    """One resumable prefill chunk against the *paged* KV pool.

    x: [B, C, d] chunk activations; cache k/v: [P, ps, KV, hd] (shared
    pools); page_table: [B, Pmax]; start: [B] absolute position of each
    row's first chunk token; n_tok: [B] valid tokens this chunk (<= C).
    Tokens past a row's n_tok are padding: their K/V writes are dropped
    and their outputs are garbage the caller must mask.

    The chunk's K/V are scattered through the page table first, then
    every query attends over the full gathered page view with the causal
    mask ``spos <= start + i`` (+ window for SWA).  Because the gathered
    view always has the same Pmax*ps length and later positions are
    masked to exact zeros, the outputs — and the written pages — are
    bitwise invariant to how a prompt is split into chunks; a single
    call covering the whole prompt is the reference semantics the
    chunked-prefill equivalence suite pins down.

    Memory note: this reference path materializes the gathered
    [B, Pmax*ps, KV, hd] view (an O(max_len) TRANSIENT, one layer at a
    time) — what chunking eliminates is the wave path's PERSISTENT
    all-layer O(max_len) scratch pytree.  The Pallas twin
    (``kernels/flash_decode.flash_prefill_paged``) streams pages
    page-by-page for a true O(chunk) footprint.

    Returns (out [B, C, d], new_cache).
    """
    b, c, d = x.shape
    dims = dims or attn_dims(cfg)
    num_pages, ps, kvh, hd = cache["k"].shape
    pmax = page_table.shape[1]
    offs = jnp.arange(c)
    positions = start[:, None] + offs[None, :]                 # [B, C]
    q, k, v = _project_qkv(cfg, params, x, positions, dims, rope=rope)
    # q: [B, KV, G, C, hd]; k/v: [B, KV, 1, C, hd]

    # scatter this chunk's K/V through the page table (flat token view;
    # padding tokens and unmapped pages -> OOB index -> dropped)
    k_tok = k[:, :, 0].transpose(0, 2, 1, 3)                   # [B, C, KV, hd]
    v_tok = v[:, :, 0].transpose(0, 2, 1, 3)
    lp = jnp.minimum(positions // ps, pmax - 1)
    phys = jnp.take_along_axis(page_table, lp, axis=1)         # [B, C]
    valid_w = (offs[None, :] < n_tok[:, None]) & (phys >= 0)
    flat_idx = jnp.where(valid_w, phys * ps + positions % ps,
                         num_pages * ps)
    kf = cache["k"].reshape(num_pages * ps, kvh, hd)
    vf = cache["v"].reshape(num_pages * ps, kvh, hd)
    kf = kf.at[flat_idx.reshape(-1)].set(
        k_tok.reshape(-1, kvh, hd).astype(kf.dtype), mode="drop")
    vf = vf.at[flat_idx.reshape(-1)].set(
        v_tok.reshape(-1, kvh, hd).astype(vf.dtype), mode="drop")
    new_cache = {"k": kf.reshape(num_pages, ps, kvh, hd),
                 "v": vf.reshape(num_pages, ps, kvh, hd)}

    # gather this batch's pages and attend with a chunk-offset query
    # window (the Pallas twin is kernels/flash_decode.flash_prefill_paged)
    pt_safe = jnp.maximum(page_table, 0)
    kg = new_cache["k"][pt_safe].reshape(b, pmax * ps, kvh, hd)
    vg = new_cache["v"][pt_safe].reshape(b, pmax * ps, kvh, hd)
    kg = kg.transpose(0, 2, 1, 3)
    vg = vg.transpose(0, 2, 1, 3)
    if kg.dtype.itemsize == 1:          # fp8 pool: dequantize for dots
        kg = kg.astype(jnp.bfloat16)
        vg = vg.astype(jnp.bfloat16)

    scale = 1.0 / np.sqrt(dims.head_dim)
    logits = jnp.einsum("bkgqh,bksh->bkgqs", q, kg,
                        preferred_element_type=jnp.float32) * scale
    spos = jnp.arange(pmax * ps)
    valid = (spos[None, None, :] <= positions[:, :, None]) & \
        jnp.repeat(page_table >= 0, ps, axis=1)[:, None, :]
    if window:
        valid &= spos[None, None, :] > positions[:, :, None] - window
    logits = jnp.where(valid[:, None, None, :, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1).astype(vg.dtype)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, vg)
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, c, dims.heads * dims.head_dim)
    return o @ params["wo"], new_cache


def attention_decode_paged(cfg: ModelConfig, params, x, cache, page_table,
                           pos, *, window: Optional[int] = None, dims=None,
                           rope: bool = True, dist=None,
                           use_flash: bool = False):
    """Single-token decode against a *paged* KV pool.

    x: [B, 1, d]; cache k/v: [P, ps, KV, hd] (the shared page pool);
    page_table: [B, Pmax] physical page per logical page (-1 = hole);
    pos: [B] absolute position of the new token.  The new token's page
    must already be mapped (the engine's allocator guarantees it);
    writes through an unmapped table entry are dropped, so padding rows
    (page_table row of -1s) are harmless.  Returns (out, new_cache).

    Sliding-window layers store the full sequence in pages and mask the
    window at read time — unlike the dense rolling buffer this keeps
    positions linear, so padded prefill garbage can never alias a live
    slot.

    ``use_flash`` routes the attention reduction through the Pallas
    ``flash_decode_paged`` kernel (page-table-driven DMA, no gathered
    [B, Pmax*ps] view, in-register dequant for fp8 pools) instead of
    the jnp gather reference.  The K/V *write* path is shared — only
    the read/softmax differs, and the kernel's fp32 online softmax
    matches the reference to accumulation-order tolerance (the
    interpret-mode parity test).  SWA layers keep the reference read
    (the decode kernel has no window mask yet).
    """
    b, s1, d = x.shape
    assert s1 == 1
    dims = dims or attn_dims(cfg)
    num_pages, ps, kvh, hd = cache["k"].shape
    pmax = page_table.shape[1]
    q = (x @ params["wq"]).reshape(b, 1, dims.heads, dims.head_dim)
    k = (x @ params["wk"]).reshape(b, 1, dims.kv, dims.head_dim)
    v = (x @ params["wv"]).reshape(b, 1, dims.kv, dims.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if rope:
        q = apply_rope(q, pos[:, None, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None, None], cfg.rope_theta)

    # write the new token through the page table (1-scatter on the flat
    # token view; unmapped pages -> OOB index -> dropped)
    lp = jnp.minimum(pos // ps, pmax - 1)
    phys = page_table[jnp.arange(b), lp]                       # [B]
    flat_idx = jnp.where(phys >= 0, phys * ps + pos % ps, num_pages * ps)
    kf = cache["k"].reshape(num_pages * ps, kvh, hd)
    vf = cache["v"].reshape(num_pages * ps, kvh, hd)
    kf = kf.at[flat_idx].set(k[:, 0].astype(kf.dtype), mode="drop")
    vf = vf.at[flat_idx].set(v[:, 0].astype(vf.dtype), mode="drop")
    new_cache = {"k": kf.reshape(num_pages, ps, kvh, hd),
                 "v": vf.reshape(num_pages, ps, kvh, hd)}

    if use_flash and not window:
        from repro.kernels.flash_decode import flash_decode_paged
        q4 = q.reshape(b, dims.kv, dims.group, dims.head_dim)
        o = flash_decode_paged(
            q4, new_cache["k"], new_cache["v"], pos, page_table,
            interpret=jax.default_backend() != "tpu")
        o = o.reshape(b, 1, dims.heads * dims.head_dim)
        return o @ params["wo"], new_cache

    # page-table-indexed read: gather this batch's pages into a
    # [B, KV, Pmax*ps, hd] view (the Pallas paged kernel streams the
    # same pages without materializing the view; kernels/flash_decode)
    pt_safe = jnp.maximum(page_table, 0)
    kg = new_cache["k"][pt_safe].reshape(b, pmax * ps, kvh, hd)
    vg = new_cache["v"][pt_safe].reshape(b, pmax * ps, kvh, hd)
    kg = kg.transpose(0, 2, 1, 3)
    vg = vg.transpose(0, 2, 1, 3)
    if kg.dtype.itemsize == 1:          # fp8 pool: dequantize for dots
        kg = kg.astype(jnp.bfloat16)
        vg = vg.astype(jnp.bfloat16)

    q = q.reshape(b, dims.kv, dims.group, dims.head_dim)
    scale = 1.0 / np.sqrt(dims.head_dim)
    logits = jnp.einsum("bkgh,bksh->bkgs", q, kg,
                        preferred_element_type=jnp.float32) * scale
    spos = jnp.arange(pmax * ps)
    valid = (spos[None, :] <= pos[:, None]) & \
        jnp.repeat(page_table >= 0, ps, axis=1)
    if window:
        valid &= spos[None, :] > pos[:, None] - window
    logits = jnp.where(valid[:, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1).astype(vg.dtype)
    o = jnp.einsum("bkgs,bksh->bkgh", p, vg)
    o = o.reshape(b, 1, dims.heads * dims.head_dim)
    return o @ params["wo"], new_cache


def attention_decode(cfg: ModelConfig, params, x, cache, pos, *,
                     window: Optional[int] = None, dims=None,
                     rope: bool = True, dist=None):
    """Single-token decode against a KV cache.

    x: [B, 1, d]; cache k/v: [B, KV, S_cache, hd]; pos: [B] absolute
    position of the new token.  SWA uses a rolling buffer (S_cache == W).
    Returns (out [B, 1, d], new_cache).
    """
    b, s1, d = x.shape
    assert s1 == 1
    dims = dims or attn_dims(cfg)
    s_cache = cache["k"].shape[2]
    q = (x @ params["wq"]).reshape(b, 1, dims.heads, dims.head_dim)
    k = (x @ params["wk"]).reshape(b, 1, dims.kv, dims.head_dim)
    v = (x @ params["wv"]).reshape(b, 1, dims.kv, dims.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if rope:
        q = apply_rope(q, pos[:, None, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None, None], cfg.rope_theta)

    slot = pos % s_cache if (window and window <= s_cache) else pos
    slot = jnp.minimum(slot, s_cache - 1)
    bidx = jnp.arange(b)
    new_k = cache["k"].at[bidx, :, slot].set(
        k[:, 0].astype(cache["k"].dtype))
    new_v = cache["v"].at[bidx, :, slot].set(
        v[:, 0].astype(cache["v"].dtype))

    q = q.reshape(b, dims.kv, dims.group, dims.head_dim)
    scale = 1.0 / np.sqrt(dims.head_dim)
    # fp8 KV cache support: dequantize for the attention dots (on TPU the
    # convert fuses into the HBM read stream -> 2x less cache traffic)
    k_c = new_k.astype(jnp.bfloat16) if new_k.dtype.itemsize == 1 else new_k
    v_c = new_v.astype(jnp.bfloat16) if new_v.dtype.itemsize == 1 else new_v
    logits = jnp.einsum("bkgh,bksh->bkgs", q, k_c,
                        preferred_element_type=jnp.float32) * scale
    spos = jnp.arange(s_cache)
    if window and window <= s_cache:
        # rolling buffer: slot j holds absolute position
        # p(j) = pos - ((pos - j) mod S); valid iff p(j) >= 0
        absp = pos[:, None] - ((pos[:, None] - spos[None, :]) % s_cache)
        valid = absp >= 0
    else:
        valid = spos[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1).astype(v_c.dtype)
    o = jnp.einsum("bkgs,bksh->bkgh", p, v_c)
    o = o.reshape(b, 1, dims.heads * dims.head_dim)
    return o @ params["wo"], {"k": new_k, "v": new_v}
