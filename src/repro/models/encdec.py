"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Encoder input is precomputed frame embeddings [B, F, d] (the assignment
stubs the mel-spectrogram/conv frontend).  Decoder layers: causal
self-attention (+ KV cache in decode) -> cross-attention over encoder
output -> GELU MLP.  Whisper uses plain LayerNorm and learned positions;
we use parametric LayerNorm and sinusoidal positions on the stub.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _ln_params(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def _ln(params, x):
    return L.layer_norm(x, params["scale"], params["bias"])


def _sinusoid(length: int, d: int):
    pos = np.arange(length)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)


def init_encdec(cfg: ModelConfig, key, dist):
    d, v = cfg.d_model, cfg.vocab_size
    keys = jax.random.split(key, 8)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"norm1": _ln_params(d),
                "attn": L.init_attention(cfg, k1, tp=dist.ep_size),
                "norm2": _ln_params(d),
                "mlp": L.init_mlp(cfg, k2)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"norm1": _ln_params(d),
                "self_attn": L.init_attention(cfg, k1, tp=dist.ep_size),
                "norm2": _ln_params(d),
                "cross_attn": L.init_attention(cfg, k2, tp=dist.ep_size),
                "norm3": _ln_params(d),
                "mlp": L.init_mlp(cfg, k3)}

    ekeys = jax.random.split(keys[0], cfg.encoder_layers)
    dkeys = jax.random.split(keys[1], cfg.num_layers)
    params = {
        "embed": jax.random.normal(keys[2], (v, d), jnp.float32) * 0.02,
        "unembed": jax.random.normal(keys[3], (d, v), jnp.float32)
        / np.sqrt(d),
        "enc_blocks": jax.tree.map(
            lambda *xs: jnp.stack(xs), *[enc_layer(k) for k in ekeys]),
        "dec_blocks": jax.tree.map(
            lambda *xs: jnp.stack(xs), *[dec_layer(k) for k in dkeys]),
        "enc_norm": _ln_params(d),
        "dec_norm": _ln_params(d),
    }
    return params


def run_encoder(cfg: ModelConfig, dist, params, frames):
    """frames: [B, F, d] stubbed embeddings -> encoder output [B, F, d]."""
    b, f, d = frames.shape
    dims = L.attn_dims(cfg, dist.ep_size)
    x = frames.astype(jnp.bfloat16) + _sinusoid(f, d).astype(jnp.bfloat16)
    x = dist.shard(x, dist.dp_axes, None, None)

    def body(x, bp):
        h = _ln(bp["norm1"], x)
        x = x + L.attention_bidir(cfg, bp["attn"], h, dims=dims)
        h = _ln(bp["norm2"], x)
        x = x + L.apply_mlp(cfg, bp["mlp"], h, dist=dist)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return _ln(params["enc_norm"], x)


def init_encdec_cache(cfg: ModelConfig, dist, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    """Decoder self-attn caches + cross-attn K/V (filled at prefill)."""
    dims = L.attn_dims(cfg, dist.ep_size)
    ld = cfg.num_layers
    kv_self = jnp.zeros((ld, batch, dims.kv, max_len, dims.head_dim), dtype)
    kv_cross = jnp.zeros(
        (ld, batch, dims.kv, cfg.encoder_frames, dims.head_dim), dtype)
    return {"self_k": kv_self, "self_v": kv_self,
            "cross_k": kv_cross, "cross_v": kv_cross}


def apply_encdec(cfg: ModelConfig, dist, params, *, tokens, embeds=None,
                 pos=None, cache=None, mode="train", chunk: int = 1024,
                 frames=None):
    """Returns (logits, new_cache, stats) mirroring apply_lm."""
    from repro.models.lm import cast_params
    params = cast_params(params)
    d = cfg.d_model
    dims = L.attn_dims(cfg, dist.ep_size)
    stats = {"aux_loss": jnp.zeros((), jnp.float32),
             "max_activated": jnp.zeros((), jnp.float32),
             "mean_activated": jnp.zeros((), jnp.float32),
             "max_tokens": jnp.zeros((), jnp.float32),
             "expert_hist": jnp.zeros((1,), jnp.float32),
             "slot_hist": jnp.zeros((1, 1), jnp.float32)}

    if mode in ("train", "prefill"):
        assert frames is not None or embeds is not None
        enc = run_encoder(cfg, dist, params,
                          frames if frames is not None else embeds)
        x = params["embed"][tokens].astype(jnp.bfloat16)
        b, s = tokens.shape
        x = x + _sinusoid(s, d).astype(jnp.bfloat16)
        x = dist.shard(x, dist.dp_axes, None, None)

        def body(x, bp):
            h = _ln(bp["norm1"], x)
            y, kv = L.attention_train(cfg, bp["self_attn"], h, dims=dims,
                                      chunk=chunk, rope=False, dist=dist,
                                      return_kv=(mode == "prefill"))
            x = x + y
            h = _ln(bp["norm2"], x)
            ckv = L.cross_kv(cfg, bp["cross_attn"], enc, dims=dims)
            x = x + L.attention_cross(cfg, bp["cross_attn"], h, ckv,
                                      dims=dims)
            h = _ln(bp["norm3"], x)
            x = x + L.apply_mlp(cfg, bp["mlp"], h, dist=dist)
            out = (kv, (ckv["k"], ckv["v"])) if mode == "prefill" else None
            return x, out

        x, caches = jax.lax.scan(body, x, params["dec_blocks"])
        x = _ln(params["dec_norm"], x)
        logits = x @ params["unembed"].astype(x.dtype)
        new_cache = None
        if mode == "prefill":
            (ks, vs), (ck, cv) = caches
            max_len = cache["self_k"].shape[3] if cache else s
            pad = max_len - s
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
            new_cache = {"self_k": ks.astype(jnp.bfloat16),
                         "self_v": vs.astype(jnp.bfloat16),
                         "cross_k": ck.astype(jnp.bfloat16),
                         "cross_v": cv.astype(jnp.bfloat16)}
        return logits, new_cache, stats

    # decode: one token per request
    assert cache is not None and pos is not None
    x = params["embed"][tokens].astype(jnp.bfloat16)   # [B, 1, d]
    b = tokens.shape[0]
    pe_table = _sinusoid(cache["self_k"].shape[3], d)
    x = x + pe_table[pos][:, None].astype(jnp.bfloat16)

    def body(x, bp_and_cache):
        bp, ck, cv, sk, sv = bp_and_cache
        h = _ln(bp["norm1"], x)
        y, new_kv = L.attention_decode(cfg, bp["self_attn"], h,
                                       {"k": sk, "v": sv}, pos,
                                       dims=dims, rope=False, dist=dist)
        x = x + y
        h = _ln(bp["norm2"], x)
        x = x + L.attention_cross(cfg, bp["cross_attn"], h,
                                  {"k": ck, "v": cv}, dims=dims)
        h = _ln(bp["norm3"], x)
        x = x + L.apply_mlp(cfg, bp["mlp"], h, dist=dist)
        return x, (new_kv["k"], new_kv["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["cross_k"], cache["cross_v"],
                  cache["self_k"], cache["self_v"]))
    x = _ln(params["dec_norm"], x)
    logits = x @ params["unembed"].astype(x.dtype)
    new_cache = dict(cache, self_k=nk, self_v=nv)
    return logits, new_cache, stats
