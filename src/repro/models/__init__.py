"""Model zoo: composable layers + per-family assemblies."""
from repro.models.lm import (
    init_lm, apply_lm, lm_loss, init_cache, build_lm_routing, cache_pspec)
from repro.models.moe import moe_ffn, init_moe, routing_tables, gating
from repro.models import layers, mamba, encdec

__all__ = [
    "init_lm", "apply_lm", "lm_loss", "init_cache", "build_lm_routing",
    "cache_pspec", "moe_ffn", "init_moe", "routing_tables", "gating",
    "layers", "mamba", "encdec",
]
