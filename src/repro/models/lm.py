"""Decoder-only LM assembly for every assigned architecture family.

Layer stacks are a `lax.scan` over *pattern blocks*: each block holds one
period of the config's layer pattern (e.g. gemma3's [5x local, 1x global],
jamba's [mamba x3, attn, mamba x3 + MoE interleave]), with parameters
stacked on a leading n_blocks axis — keeping the HLO O(period) regardless
of depth (95-layer deepseek compiles as fast as 16-layer olmo).

Modes:
  train   — full causal pass, logits for loss; no cache.
  prefill — causal pass that also *fills* the KV/SSM caches.
  decode  — single token against caches (the paper's memory-bound phase);
            MoE layers run in features mode with METRO routing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.core.types import Placement
from repro.sharding.policy import Dist


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, key, dist: Dist, mixer: str, ffn: str,
                replica_expert):
    ks = jax.random.split(key, 4)
    p = {"norm1": L.init_norm(cfg, ks[0])}
    if mixer.startswith("attn"):
        p["attn"] = L.init_attention(cfg, ks[1], tp=dist.ep_size)
    elif mixer == "mamba":
        p["mamba"] = M.init_mamba(cfg, ks[1])
    if ffn == "dense":
        p["norm2"] = L.init_norm(cfg, ks[2])
        p["mlp"] = L.init_mlp(cfg, ks[3])
    elif ffn == "moe":
        p["norm2"] = L.init_norm(cfg, ks[2])
        p["moe"] = MOE.init_moe(cfg, ks[3], dist, replica_expert)
    return p


def init_lm(cfg: ModelConfig, key, dist: Dist,
            replica_expert: Optional[np.ndarray] = None):
    """Full parameter pytree (fp32 master). MoE layers need the physical
    replica layout (replica_expert from the placement)."""
    if cfg.family == "encdec":
        from repro.models import encdec
        return encdec.init_encdec(cfg, key, dist)
    kinds = cfg.layer_kinds()
    n_blocks = cfg.num_layers // len(kinds)
    k_emb, k_blocks, k_norm, k_head = jax.random.split(key, 4)
    d, v = cfg.d_model, cfg.vocab_size
    params = {}
    # even embeddings-mode archs (VLM stub) keep a token table: prefill
    # consumes precomputed patch embeddings, decode embeds generated text
    params["embed"] = jax.random.normal(k_emb, (v, d), jnp.float32) * 0.02
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            k_head, (d, v), jnp.float32) / np.sqrt(d)
    params["final_norm"] = L.init_norm(cfg, k_norm)

    bkeys = jax.random.split(k_blocks, n_blocks)

    def one_block(bk):
        lkeys = jax.random.split(bk, len(kinds))
        return {f"l{i}": _init_layer(cfg, lkeys[i], dist, mixer, ffn,
                                     replica_expert)
                for i, (mixer, ffn) in enumerate(kinds)}

    blocks = [one_block(bk) for bk in bkeys]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return params


def build_lm_routing(cfg: ModelConfig, placement: Placement,
                     table_width: Optional[int] = None):
    """Per-layer routing tables, stacked over blocks (same placement for
    every MoE layer by default; the serving engine may rebalance
    per-layer by stacking different placements)."""
    if not cfg.is_moe:
        return {}
    kinds = cfg.layer_kinds()
    n_blocks = cfg.num_layers // len(kinds)
    t = MOE.routing_tables(placement, table_width)
    out = {}
    for i, (_, ffn) in enumerate(kinds):
        if ffn == "moe":
            out[f"l{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_blocks,) + a.shape), t)
    return out


# ----------------------------------------------------------------------
# caches
# ----------------------------------------------------------------------


def init_cache(cfg: ModelConfig, dist: Dist, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Decode caches for all layers, stacked over blocks."""
    if cfg.family == "encdec":
        from repro.models import encdec
        return encdec.init_encdec_cache(cfg, dist, batch, max_len, dtype)
    kinds = cfg.layer_kinds()
    n_blocks = cfg.num_layers // len(kinds)
    cache = {}
    for i, (mixer, _) in enumerate(kinds):
        if mixer == "attn_full":
            c = L.init_kv_cache(cfg, batch, max_len, None, dtype,
                                tp=dist.ep_size)
        elif mixer == "attn_swa":
            c = L.init_kv_cache(cfg, batch, max_len, cfg.sliding_window,
                                dtype, tp=dist.ep_size)
        elif mixer == "mamba":
            c = M.init_mamba_cache(cfg, batch, dtype)
        else:
            continue
        cache[f"l{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_blocks,) + a.shape), c)
    return cache


def init_paged_cache(cfg: ModelConfig, dist: Dist, num_pages: int,
                     page_size: int, max_batch: int, dtype=jnp.bfloat16):
    """Serving cache with paged attention layers: per attention layer a
    shared page pool [n_blocks, num_pages, page_size, kv, hd]; mamba
    layers keep per-slot state (their state is O(1) per sequence, there
    is nothing to page).  ``dtype`` sets the attention pool element
    type (the engine's ``kv_dtype``: bf16/fp32/fp8 — paged reads are
    dequant-aware); mamba recurrence state is never quantized below
    bf16 (it feeds a sequential scan, not a dequantizing gather)."""
    kinds = cfg.layer_kinds()
    n_blocks = cfg.num_layers // len(kinds)
    mamba_dtype = jnp.bfloat16 if jnp.dtype(dtype).itemsize == 1 else dtype
    cache = {}
    for i, (mixer, _) in enumerate(kinds):
        if mixer.startswith("attn"):
            c = L.init_paged_kv_cache(cfg, num_pages, page_size, dtype,
                                      tp=dist.ep_size)
        elif mixer == "mamba":
            c = M.init_mamba_cache(cfg, max_batch, mamba_dtype)
        else:
            continue
        cache[f"l{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_blocks,) + a.shape), c)
    return cache


def init_wave_cache(cfg: ModelConfig, dist: Dist, batch: int, length: int,
                    dtype=jnp.bfloat16):
    """Scratch cache for one batched prefill wave: attention buffers are
    FULL length (never rolling) so every position lands at its own index
    and can be scattered into the serving cache afterwards.

    Legacy path: only ``prefill_mode="wave"`` (and the dense KV layout)
    still allocates this persistent O(batch * length * n_layers)
    scratch — the engine's default chunked prefill
    (``mode="chunk_prefill"``) writes each O(prefill_chunk) chunk
    straight into the paged serving cache and allocates no full-length
    wave scratch at all (see attention_prefill_paged's memory note for
    the reference path's per-layer transient)."""
    kinds = cfg.layer_kinds()
    n_blocks = cfg.num_layers // len(kinds)
    cache = {}
    for i, (mixer, _) in enumerate(kinds):
        if mixer.startswith("attn"):
            c = L.init_kv_cache(cfg, batch, length, None, dtype,
                                tp=dist.ep_size)
        elif mixer == "mamba":
            c = M.init_mamba_cache(cfg, batch, dtype)
        else:
            continue
        cache[f"l{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_blocks,) + a.shape), c)
    return cache


def merge_wave_cache(cfg: ModelConfig, cache, wave_cache, slot_idx,
                     lengths, *, page_table=None, page_size: int = 0):
    """Scatter a prefill wave's filled scratch cache into the serving
    cache (jit-traceable; called inside the wave-prefill step).

    cache: engine cache — paged pools when ``page_table`` is given, else
    dense per-slot buffers.  wave_cache: from :func:`init_wave_cache`
    after ``apply_lm(mode="prefill")``.  slot_idx: [B] engine slot per
    wave row (out-of-range = padding row, dropped).  lengths: [B] true
    prompt lengths (positions beyond a row's length are not scattered
    into pages).  page_table: [B, Pmax] physical page per logical page.
    """
    wb = len(slot_idx)
    out = {}
    for li, full in cache.items():
        wave = wave_cache[li]
        if "conv" in full:                       # mamba: per-slot rows
            out[li] = jax.tree.map(
                lambda f, p: f.at[:, slot_idx].set(
                    p.astype(f.dtype), mode="drop"), full, wave)
            continue
        # attention: wave k/v [nb, B, kv, L, hd]
        l_pad = wave["k"].shape[3]
        if page_table is not None:
            ps = page_size
            tt = jnp.broadcast_to(jnp.arange(l_pad), (wb, l_pad))
            phys = jnp.take_along_axis(page_table, tt // ps, axis=1)
            valid = (tt < lengths[:, None]) & (phys >= 0)
            num_pages = full["k"].shape[1]
            flat_idx = jnp.where(valid, phys * ps + tt % ps,
                                 num_pages * ps).reshape(-1)

            def scatter(pool, w):
                nb, p, ps_, kvh, hd = pool.shape
                vals = w.transpose(0, 1, 3, 2, 4).reshape(
                    nb, wb * l_pad, kvh, hd)
                flat = pool.reshape(nb, p * ps_, kvh, hd)
                flat = flat.at[:, flat_idx].set(
                    vals.astype(flat.dtype), mode="drop")
                return flat.reshape(pool.shape)

            out[li] = {k: scatter(full[k], wave[k]) for k in ("k", "v")}
        else:
            s_buf = full["k"].shape[3]
            if l_pad <= s_buf:
                out[li] = {
                    k: full[k].at[:, slot_idx, :, :l_pad].set(
                        wave[k].astype(full[k].dtype), mode="drop")
                    for k in ("k", "v")}
            else:
                # rolling (SWA) buffer: keep each row's last s_buf REAL
                # positions at slots p % s_buf (attention_decode's
                # mapping).  Per-row gather — taking the padded tail
                # would both store garbage keys and roll real in-window
                # context out of the buffer.
                sel = jnp.asarray(slot_idx)[:, None]
                src_pos = lengths[:, None] - s_buf + \
                    jnp.arange(s_buf)[None, :]          # [B, s_buf]
                dst = jnp.where(src_pos >= 0, src_pos % s_buf, s_buf)

                def roll(f, w):
                    g = jnp.take_along_axis(
                        w, jnp.clip(src_pos, 0, l_pad - 1)[
                            None, :, None, :, None], axis=3)
                    vals = g.transpose(1, 3, 0, 2, 4)   # [B,s_buf,nb,kv,hd]
                    return f.at[:, sel, :, dst].set(
                        vals.astype(f.dtype), mode="drop")

                out[li] = {k: roll(full[k], wave[k]) for k in ("k", "v")}
    return out


def cache_pspec(cfg: ModelConfig, dist: Dist, long_context: bool = False):
    """PartitionSpecs for the cache pytree (for dry-run in_shardings).

    KV: heads sharded over the TP axis; for long-context cells the
    sequence dim is additionally sharded over the data axes.
    Mamba: channels over TP.
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.layers import attn_dims
    kinds = cfg.layer_kinds()
    ax, dp = dist.tp_axis, dist.dp_axes
    kv_ok = (dist.mesh is not None and ax is not None
             and attn_dims(cfg, dist.ep_size).kv % dist.ep_size == 0)
    specs = {}
    for i, (mixer, _) in enumerate(kinds):
        if mixer.startswith("attn"):
            # batch over DP; long-context (batch=1) shards the KV
            # sequence over the data axes instead (DESIGN.md §7).
            # kv heads shard over TP when divisible, else the sequence
            # dim takes the TP axis (no head padding — see attn_dims).
            batch_ax = None if long_context else dp
            head_ax = ax if kv_ok else None
            if long_context and mixer == "attn_full":
                seq_ax = dp if kv_ok else tuple(dp) + (ax,)
            else:
                seq_ax = None if kv_ok else ax
            s = P(None, batch_ax, head_ax, seq_ax, None)
            specs[f"l{i}"] = {"k": s, "v": s}
        elif mixer == "mamba":
            batch_ax = None if long_context else dp
            specs[f"l{i}"] = {"conv": P(None, batch_ax, None, ax),
                              "h": P(None, batch_ax, ax, None)}
    return specs


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------


def cast_params(params, dtype=jnp.bfloat16):
    """Cast float params to the compute dtype (mixed-precision fwd);
    numerically-sensitive leaves are re-upcast inside their layers."""
    return jax.tree.map(
        lambda a: a.astype(dtype)
        if hasattr(a, "dtype") and a.dtype == jnp.float32 else a, params)


def _mixer_apply(cfg, dist, lp, mixer, x, *, mode, lc, pos, chunk,
                 slot_idx=None, page_table=None, row_valid=None,
                 use_flash=False):
    """Apply attention/mamba; returns (y, new_layer_cache or {}).

    Decode-time serving extensions: ``slot_idx`` gathers only the active
    cache rows into the (bucketed) batch and scatters updates back
    (out-of-range entries are padding rows and are dropped);
    ``page_table`` switches attention layers to the paged KV pool.
    """
    window = cfg.sliding_window if mixer == "attn_swa" else None
    if mode == "chunk_prefill":
        # resumable chunked prefill: a [B, C] chunk runs against the
        # SERVING cache (paged pools / per-slot mamba state) instead of a
        # full-length wave scratch buffer.  ``pos`` is each row's chunk
        # start; row_valid's per-row prefix length is the chunk's n_tok.
        n_tok = (jnp.sum(row_valid.astype(jnp.int32), axis=1)
                 if row_valid is not None
                 else jnp.full((x.shape[0],), x.shape[1], jnp.int32))
        if mixer == "mamba":
            if slot_idx is None:
                return M.mamba_chunk(cfg, lp["mamba"], x, lc, n_tok,
                                     dist=dist)
            rows = jax.tree.map(
                lambda a: a[jnp.minimum(slot_idx, a.shape[0] - 1)], lc)
            y, nc = M.mamba_chunk(cfg, lp["mamba"], x, rows, n_tok,
                                  dist=dist)
            nc = jax.tree.map(
                lambda full, part: full.at[slot_idx].set(
                    part.astype(full.dtype), mode="drop"), lc, nc)
            return y, nc
        assert page_table is not None, \
            "chunked prefill requires the paged KV layout"
        return L.attention_prefill_paged(
            cfg, lp["attn"], x, lc, page_table, pos, n_tok,
            window=window, dims=L.attn_dims(cfg, dist.ep_size), dist=dist)
    if mixer == "mamba":
        if mode == "decode":
            if slot_idx is None:
                return M.mamba_decode(cfg, lp["mamba"], x, lc, dist=dist)
            rows = jax.tree.map(
                lambda a: a[jnp.minimum(slot_idx, a.shape[0] - 1)], lc)
            y, nc = M.mamba_decode(cfg, lp["mamba"], x, rows, dist=dist)
            nc = jax.tree.map(
                lambda full, part: full.at[slot_idx].set(
                    part.astype(full.dtype), mode="drop"), lc, nc)
            return y, nc
        # prefill on a length-padded batch: hand the decode cache off at
        # each row's true last position (the recurrence has no position
        # mask, so the final state would have absorbed padding tokens)
        lengths = (jnp.sum(row_valid, axis=1)
                   if mode == "prefill" and row_valid is not None
                   and row_valid.ndim == 2 else None)
        y, st = M.mamba_train(cfg, lp["mamba"], x, dist=dist,
                              return_state=(mode == "prefill"),
                              lengths=lengths)
        return y, (st if mode == "prefill" else {})
    dims = L.attn_dims(cfg, dist.ep_size)
    # attention
    if mode == "decode":
        if page_table is not None:
            return L.attention_decode_paged(
                cfg, lp["attn"], x, lc, page_table, pos,
                window=window, dims=dims, dist=dist,
                use_flash=use_flash)
        if slot_idx is not None:
            rows = {k: v[jnp.minimum(slot_idx, v.shape[0] - 1)]
                    for k, v in lc.items()}
            y, nc_rows = L.attention_decode(cfg, lp["attn"], x, rows, pos,
                                            window=window, dims=dims,
                                            dist=dist)
            nc = {k: lc[k].at[slot_idx].set(nc_rows[k], mode="drop")
                  for k in lc}
            return y, nc
        return L.attention_decode(cfg, lp["attn"], x, lc, pos,
                                  window=window, dims=dims, dist=dist)
    y, kv = L.attention_train(cfg, lp["attn"], x, window=window, dims=dims,
                              chunk=chunk, dist=dist,
                              return_kv=(mode == "prefill"))
    if mode != "prefill":
        return y, {}
    # fill the cache buffers from the prefill K/V
    k, v = kv
    s = x.shape[1]
    buf_k, buf_v = lc["k"], lc["v"]
    w = buf_k.shape[2]
    if window and w <= s:
        kw, vw = k[:, :, -w:], v[:, :, -w:]
        slots = (jnp.arange(s - w, s) % w)
        new_k = buf_k.at[:, :, slots].set(kw.astype(buf_k.dtype))
        new_v = buf_v.at[:, :, slots].set(vw.astype(buf_v.dtype))
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(
            buf_k, k.astype(buf_k.dtype), 0, axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            buf_v, v.astype(buf_v.dtype), 0, axis=2)
    return y, {"k": new_k, "v": new_v}


_REMAT_POLICIES = {
    "dots_no_batch": lambda: jax.checkpoint_policies
    .dots_with_no_batch_dims_saveable,
    "dots": lambda: jax.checkpoint_policies.everything_saveable,
    "nothing": lambda: jax.checkpoint_policies.nothing_saveable,
    "save_moe": lambda: jax.checkpoint_policies.save_only_these_names(
        "moe_h", "moe_y"),
}


def apply_lm(cfg: ModelConfig, dist: Dist, params, *, tokens=None,
             embeds=None, pos=None, cache=None, routing=None,
             mode: str = "train", algo: str = "eplb",
             moe_impl: str = "ragged", chunk: int = 1024,
             remat: bool = False, capacity_factor: float = 1.25,
             use_pallas_route: bool = False, frames=None,
             compute_dtype=jnp.bfloat16, remat_policy: str = "dots_no_batch",
             slot_idx=None, page_table=None, row_valid=None,
             use_flash_kernel: bool = False):
    """Returns (logits, new_cache, stats).

    Serving (decode) extras: ``slot_idx`` [B] selects which cache rows
    this (bucketed) batch occupies; ``page_table`` [B, Pmax] switches
    attention to paged-KV pools (cache from :func:`init_paged_cache`);
    ``row_valid`` (bool, [B] decode / [B, S] prefill) keeps padding
    tokens out of MoE routing, making routing decisions — and therefore
    the numerics — invariant to batch-bucket and length padding;
    ``use_flash_kernel`` runs paged decode attention through the Pallas
    ``flash_decode_paged`` kernel (full-attention layers only — SWA
    keeps the gather reference).

    ``moe_impl`` selects the grouped expert-FFN datapath per MoE layer:
    ``"ragged"`` (lax.ragged_dot, the XLA fast path), ``"scan_tiles"``,
    ``"onehot"`` (oracle), ``"pallas"`` (two-pass Pallas kernel), or
    ``"fused"`` (one-pass up→act→down Pallas megakernel — the hidden
    activation never touches HBM; forward/serving only, train with a
    two-pass impl; kernels/README.md has the matrix).
    ``use_pallas_route`` moves METRO's Alg. 1 greedy onto the Pallas
    scalar-core kernel.

    ``mode="chunk_prefill"``: resumable chunked prefill.  ``tokens`` is
    a [B, C] chunk, ``pos`` [B] the absolute position of each row's
    first chunk token, ``cache`` the SERVING cache (paged pools +
    per-slot mamba state — no wave scratch buffer), ``row_valid``
    [B, C] a per-row contiguous prefix mask (its row-sum is the chunk's
    valid-token count).  Attention reads already-written pages, mamba
    carries {conv, h} across calls, so any chunk split of a prompt is
    bitwise identical to one monolithic chunk_prefill call — the
    invariant tests/test_chunked_prefill.py locks down.
    """
    if cfg.family == "encdec":
        from repro.models import encdec
        return encdec.apply_encdec(
            cfg, dist, params, tokens=tokens, embeds=embeds, pos=pos,
            cache=cache, mode=mode, chunk=chunk, frames=frames)

    kinds = cfg.layer_kinds()
    n_blocks = cfg.num_layers // len(kinds)
    dp = dist.dp_axes
    params = cast_params(params, compute_dtype)

    if cfg.input_mode == "embeddings" and mode != "decode":
        x = embeds
    else:
        x = params["embed"][tokens]
    x = x.astype(compute_dtype)
    x = dist.shard(x, dp, None, None)

    routing = routing or {}
    cache = cache or {}
    moe_mode = "features" if mode == "decode" else "tokens"

    def block_body(x, blk):
        bp, bc, brt = blk
        new_bc = {}
        stats_l = []
        for i, (mixer, ffn) in enumerate(kinds):
            li = f"l{i}"
            lp = bp[li]
            h = L.apply_norm(cfg, lp["norm1"], x)
            y, nc = _mixer_apply(cfg, dist, lp, mixer, h, mode=mode,
                                 lc=bc.get(li), pos=pos, chunk=chunk,
                                 slot_idx=slot_idx, page_table=page_table,
                                 row_valid=row_valid,
                                 use_flash=use_flash_kernel)
            if nc:
                new_bc[li] = nc
            # cast keeps the residual stream in the compute dtype even
            # when the mixer read a wider KV pool (kv_dtype="fp32")
            x = x + y.astype(x.dtype)
            if ffn != "none":
                h2 = L.apply_norm(cfg, lp["norm2"], x)
                if ffn == "dense":
                    y2 = L.apply_mlp(cfg, lp["mlp"], h2, dist=dist)
                else:
                    if moe_mode == "features":
                        h2f = h2[:, 0]          # [B, 1, d] -> [B, d]
                        y2, st = MOE.moe_ffn(
                            cfg, dist, lp["moe"], brt[li], h2f, algo=algo,
                            impl=moe_impl, mode="features",
                            capacity_factor=capacity_factor,
                            use_pallas_route=use_pallas_route,
                            row_valid=row_valid)
                        y2 = y2[:, None]
                    else:
                        y2, st = MOE.moe_ffn(
                            cfg, dist, lp["moe"], brt[li], h2, algo=algo,
                            impl=moe_impl, mode="tokens",
                            capacity_factor=capacity_factor,
                            use_pallas_route=use_pallas_route,
                            row_valid=row_valid)
                    stats_l.append(st)
                x = x + y2.astype(x.dtype)
        if stats_l:
            stats = jax.tree.map(lambda *v: jnp.stack(v), *stats_l)
        else:
            stats = {}
        return x, (new_bc, stats)

    body = block_body
    if remat and mode == "train":
        body = jax.checkpoint(
            block_body, policy=_REMAT_POLICIES[remat_policy]())

    x, (new_cache, stats) = jax.lax.scan(
        body, x, (params["blocks"], cache, routing))

    x = L.apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        unembed = params["embed"].T
    else:
        unembed = params["unembed"]
    logits = x @ unembed.astype(x.dtype)
    logits = dist.shard(logits, dp, None, dist.tp_axis)

    # reduce per-(block, layer) stats
    if stats:
        stats = {
            "aux_loss": jnp.mean(stats["aux_loss"]),
            "max_activated": jnp.max(stats["max_activated"]),
            "mean_activated": jnp.mean(stats["mean_activated"]),
            "max_tokens": jnp.max(stats["max_tokens"]),
            # summed over layers -> rebalance signal [N]
            "expert_hist": jnp.sum(stats["expert_hist"], axis=(0, 1)),
            # kept per MoE layer [L_moe, R] (layer order): the expert
            # pool pages weights per (layer, slot), so the executor
            # replays layers in sequence, not a summed blur
            "slot_hist": stats["slot_hist"].reshape(
                -1, stats["slot_hist"].shape[-1]),
        }
    else:
        stats = {"aux_loss": jnp.zeros((), jnp.float32),
                 "max_activated": jnp.zeros((), jnp.float32),
                 "mean_activated": jnp.zeros((), jnp.float32),
                 "max_tokens": jnp.zeros((), jnp.float32),
                 "expert_hist": jnp.zeros((max(cfg.num_experts, 1),),
                                          jnp.float32),
                 "slot_hist": jnp.zeros((1, 1), jnp.float32)}
    return logits, new_cache, stats


def lm_loss(cfg: ModelConfig, dist: Dist, params, batch, *, routing=None,
            algo: str = "eplb", moe_impl: str = "ragged",
            remat: bool = False, aux_coef: float = 0.01,
            chunk: int = 1024, remat_policy: str = "dots_no_batch"):
    """Mean next-token NLL + MoE aux loss. Labels are pre-shifted."""
    logits, _, stats = apply_lm(
        cfg, dist, params, tokens=batch.get("tokens"),
        embeds=batch.get("embeds"), frames=batch.get("frames"),
        routing=routing, mode="train",
        algo=algo, moe_impl=moe_impl, remat=remat, chunk=chunk,
        remat_policy=remat_policy)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - ll)
    loss = nll + aux_coef * stats["aux_loss"]
    stats = dict(stats, nll=nll)
    return loss, stats
