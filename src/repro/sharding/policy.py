"""Distribution context + sharding policy.

One object (:class:`Dist`) threads through the model code and answers:
  * is a mesh active, and what are the axis names?
  * how big is the EP group / how many replica slots per device?
  * what PartitionSpec should tensor X get (with divisibility fallback)?

Model code never imports jax.sharding directly — it calls
``dist.shard(x, ...)`` which is the identity when no mesh is active, so
the same model runs on 1 CPU device (smoke tests) and on the 512-chip
production mesh (dry-run) unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Dist:
    mesh: Optional[Mesh]
    dp_axes: tuple[str, ...]      # batch-sharding axes, e.g. ("pod","data")
    tp_axis: Optional[str]        # tensor/expert-parallel axis ("model")
    ep_size: int                  # EP group size (mesh tp size, or virtual)
    slots_per_device: int         # replica slots per EP rank
    # sequence-parallel MoE dispatch (paper's all-gather scheme) on/off
    ep_mode: str = "paper"        # "paper" (explicit SP all-gather) | "fused"

    # ------------------------------------------------------------------
    @property
    def num_slots(self) -> int:
        return self.ep_size * self.slots_per_device

    @property
    def dp_size(self) -> int:
        if not self.mesh:
            return 1
        return int(
            __import__("numpy").prod([self.mesh.shape[a] for a in self.dp_axes]))

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name] if self.mesh else 1

    # ------------------------------------------------------------------
    def _ok(self, dim: int, axes) -> bool:
        if not self.mesh or axes is None:
            return False
        if isinstance(axes, str):
            axes = (axes,)
        import numpy as np
        size = int(np.prod([self.mesh.shape[a] for a in axes]))
        return dim % size == 0

    def spec(self, x, *axes) -> P:
        """PartitionSpec for x with per-dim divisibility fallback: any dim
        not divisible by its axis group falls back to replication."""
        out = []
        for dim, ax in zip(x.shape, axes):
            out.append(ax if self._ok(dim, ax) else None)
        return P(*out)

    def shard(self, x, *axes):
        """with_sharding_constraint under a mesh; identity otherwise."""
        if not self.mesh:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(x, *axes)))

    def named(self, spec: P) -> Optional[NamedSharding]:
        if not self.mesh:
            return None
        return NamedSharding(self.mesh, spec)


LOCAL = Dist(mesh=None, dp_axes=(), tp_axis=None, ep_size=1,
             slots_per_device=1)


# ----------------------------------------------------------------------
# parameter sharding rules (by leaf name)
# ----------------------------------------------------------------------

# base specs WITHOUT the leading n_blocks stacking dim.
# `M` = TP/EP axis ("model"); `D` = in-pod data axis (ETP / FSDP).
_PARAM_RULES: dict[str, tuple] = {
    "embed": ("M", None), "unembed": (None, "M"),
    "wq": (None, "M"), "wk": (None, "M"), "wv": (None, "M"),
    "wo": ("M", None),
    "w_gate": (None, "M"), "w_down": ("M", None),
    # MoE: slots over M, expert-hidden over D (intra-expert TP)
    "shared_up": (None, None, ("D", "M")), "shared_down": (("D", "M"), None),
    "w_router": (None, None),
    # mamba
    "w_in": (None, "M"), "conv_w": (None, "M"), "conv_b": ("M",),
    "w_x": ("M", None), "w_dt": (None, "M"), "dt_bias": ("M",),
    "A_log": ("M", None), "D": ("M",), "w_out": ("M", None),
    # norms & misc: replicated
    "scale": (), "bias": (), "q_norm": (), "k_norm": (),
}
# w_up: MLP [d, f] rule in 2-D; MoE slot-major [R, d, n_up, fe] in 4-D.
_WUP_2D = (None, "M")
_WUP_4D = ("M", None, None, "D")
_WDOWN_3D = ("M", "D", None)

# FSDP (train): additionally shard the replicated large dim over D so
# master params + AdamW moments are fully sharded (ZeRO-3-style; XLA
# inserts the per-layer weight all-gathers).
_FSDP_RULES: dict[str, tuple] = {
    "embed": ("M", "D"), "unembed": ("D", "M"),
    "wq": ("D", "M"), "wk": ("D", "M"), "wv": ("D", "M"),
    "wo": ("M", "D"),
    "w_gate": ("D", "M"), "w_down": ("M", "D"),
    "w_in": ("D", "M"), "w_x": ("M", "D"), "w_dt": ("D", "M"),
    "A_log": ("M", None), "w_out": ("M", "D"),
}
_WUP_2D_FSDP = ("D", "M")


def param_pspecs(params, dist: Dist, *, fsdp: bool = False,
                 kv_replicated: bool = False):
    """PartitionSpec pytree for a parameter (or optimizer-state) pytree.

    Rules are by leaf name with per-dim divisibility fallback; leaves
    under a blocks stack get a leading replicated dim.

    kv_replicated: when KV heads don't divide the TP axis, sharding the
    flattened wk/wv columns forces per-layer activation all-gathers of
    K/V; replicating wk/wv over the TP axis instead recomputes the tiny
    KV projections redundantly and removes those collectives entirely
    (perf iteration, EXPERIMENTS.md §Perf).
    """
    import numpy as np
    ax = dist.tp_axis
    mesh = dist.mesh
    d_ax = "data" if (mesh is not None and "data" in mesh.axis_names) \
        else None

    def sub(a):
        if a == "M":
            return ax
        if a == "D":
            return d_ax
        if isinstance(a, tuple):
            resolved = tuple(x for x in (sub(i) for i in a) if x)
            return resolved or None
        return a

    def ok(dim, a):
        if mesh is None or a is None:
            return False
        axes = a if isinstance(a, tuple) else (a,)
        return dim % int(np.prod([mesh.shape[x] for x in axes])) == 0

    def one(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        name = names[-1]
        stacked = any(n in ("blocks", "enc_blocks", "dec_blocks")
                      for n in names)
        shape = leaf.shape
        rank = len(shape)
        eff_rank = rank - int(stacked)
        if name == "w_up":
            base = _WUP_4D if eff_rank == 4 else \
                (_WUP_2D_FSDP if fsdp else _WUP_2D)
        elif name == "w_down" and eff_rank == 3:
            base = _WDOWN_3D
        elif kv_replicated and name in ("wk", "wv"):
            base = ("D", None) if fsdp else (None, None)
        elif fsdp and name in _FSDP_RULES:
            base = _FSDP_RULES[name]
        else:
            base = _PARAM_RULES.get(name, tuple([None] * eff_rank))
        base = tuple(sub(a) for a in base)
        if stacked:
            base = (None,) + base
        base = base + (None,) * (rank - len(base))
        spec = tuple(a if ok(d, a) else None
                     for d, a in zip(shape, base))
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params)


def named_pspecs(tree_of_pspecs, dist: Dist):
    if dist.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(dist.mesh, s),
                        tree_of_pspecs,
                        is_leaf=lambda s: isinstance(s, P))


def make_dist(mesh: Optional[Mesh], *, slots_per_device: int = 1,
              ep_size: Optional[int] = None, ep_mode: str = "paper") -> Dist:
    """Build a Dist from a mesh (production) or virtual sizes (tests)."""
    if mesh is None:
        return Dist(mesh=None, dp_axes=(), tp_axis=None,
                    ep_size=ep_size or 1, slots_per_device=slots_per_device,
                    ep_mode=ep_mode)
    names = mesh.axis_names
    tp = "model" if "model" in names else None
    dp = tuple(n for n in names if n != "model")
    return Dist(mesh=mesh, dp_axes=dp, tp_axis=tp,
                ep_size=mesh.shape[tp] if tp else 1,
                slots_per_device=slots_per_device, ep_mode=ep_mode)
