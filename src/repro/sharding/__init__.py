from repro.sharding.policy import Dist, LOCAL, make_dist
__all__ = ["Dist", "LOCAL", "make_dist"]
