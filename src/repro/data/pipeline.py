"""Deterministic, restart-safe token data pipeline.

Synthetic backend: a mixture of Zipfian unigrams + short repeated motifs
(so a ~100M model actually has structure to learn), generated on the fly
from (seed, step) — which makes the pipeline *stateless*: resuming from
step k reproduces exactly the batches a non-interrupted run would see
(critical for bitwise checkpoint/restart tests).  File backend: memmaps
a flat uint16/uint32 token file and strides it per (host, step).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    backend: str = "synthetic"          # synthetic | file
    path: Optional[str] = None
    num_hosts: int = 1
    host_id: int = 0


def _zipf_probs(v: int, alpha: float = 1.1) -> np.ndarray:
    p = 1.0 / np.power(np.arange(1, v + 1), alpha)
    return p / p.sum()


_MOTIF_LEN = 16
_N_MOTIFS = 64


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Batch for `step`, deterministic in (seed, step, host sharding)."""
    b_local = cfg.global_batch // cfg.num_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    motif_rng = np.random.default_rng(cfg.seed)  # shared across steps
    motifs = motif_rng.integers(
        0, cfg.vocab_size, (_N_MOTIFS, _MOTIF_LEN)).astype(np.int32)
    probs = _zipf_probs(cfg.vocab_size)
    toks = rng.choice(cfg.vocab_size, size=(b_local, cfg.seq_len + 1),
                      p=probs).astype(np.int32)
    # splice motifs so there is learnable n-gram structure
    n_splice = max(cfg.seq_len // (2 * _MOTIF_LEN), 1)
    for i in range(b_local):
        starts = rng.integers(0, cfg.seq_len + 1 - _MOTIF_LEN, n_splice)
        which = rng.integers(0, _N_MOTIFS, n_splice)
        for s, w in zip(starts, which):
            toks[i, s:s + _MOTIF_LEN] = motifs[w]
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def file_batch(cfg: DataConfig, step: int, mm: np.ndarray) -> dict:
    b_local = cfg.global_batch // cfg.num_hosts
    span = cfg.seq_len + 1
    n_seq = (len(mm) - 1) // span
    base = (step * cfg.global_batch + cfg.host_id * b_local) % max(
        n_seq - b_local, 1)
    toks = np.stack([mm[(base + i) * span:(base + i + 1) * span]
                     for i in range(b_local)]).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_dataset(cfg: DataConfig):
    if cfg.backend == "file":
        assert cfg.path, "file backend needs a path"
        dtype = np.uint32 if cfg.vocab_size > 65535 else np.uint16
        mm = np.memmap(cfg.path, dtype=dtype, mode="r")
        return lambda step: file_batch(cfg, step, mm)
    return lambda step: synthetic_batch(cfg, step)


def data_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    ds = make_dataset(cfg)
    step = start_step
    while True:
        yield ds(step)
        step += 1
