from repro.data.pipeline import DataConfig, make_dataset, data_iterator

__all__ = ["DataConfig", "make_dataset", "data_iterator"]
