"""Latency/SLO bookkeeping: TTFT, TPOT, throughput, percentiles, and
engine-health counters (step-function compiles, preemptions, queue
depth, decode-stall attribution).

The compile counter is the observable for batch bucketing: every time
the engine builds a step function for a new (kind, signature) pair it
calls :meth:`compiled`, so ``summary()["total_compiles"]`` counts XLA
tracings — the quantity power-of-two bucketing + wave prefill bound to
O(log max_batch · log max_len) regardless of trace length.

Chunked prefill adds two attributions:

  * **TTFT decomposition** — each request's TTFT splits into queue wait
    (arrival → admission), prefill span (first chunk issued → last chunk
    done) and decode wait (prefill done → first token), via the
    :meth:`admitted` / :meth:`prefill_started` / :meth:`prefill_done`
    events the engine emits per chunk boundary.
  * **decode-stall attribution** — :meth:`stall` records every second a
    prefill-carrying call ran while decode-phase rows sat waiting
    (wave prefill stalls for the whole prompt, chunked prefill for one
    chunk, mixed steps not at all — decode rides in the same call).

The prefix cache adds :meth:`prefix_hit`: context tokens served from
cached KV pages skip prefill entirely, so the per-request
``n_prefix_hit`` splits the TTFT population into cached vs cold
(``ttft_mean_hit`` / ``ttft_mean_cold`` in :meth:`summary`) and
``prefix_hit_tokens`` counts the prefill work the cache saved.

All timestamps come from an injectable ``clock`` (defaults to
``time.perf_counter``), so every derived metric is unit-testable on
hand-built timelines (tests/test_slo.py).

Cluster additions: :class:`VirtualClock` is the injectable clock the
multi-replica simulation advances by a modeled per-step cost (making
SLO sweeps bit-reproducible on CPU), and
:func:`aggregate_cluster_summary` pools many replicas' trackers into
one cluster-level rollup (pooled TTFT/TPOT percentiles + per-replica
breakdown) — the quantity the Pareto-at-SLO harness binary-searches.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict

import numpy as np


@dataclasses.dataclass
class RequestTiming:
    arrival: float
    admitted: float = 0.0        # last admission (re-set on readmission)
    prefill_start: float = 0.0   # first prefill chunk issued
    prefill_done: float = 0.0    # last prefill chunk finished
    first_token: float = 0.0
    finished: float = 0.0
    n_prompt: int = 0
    n_generated: int = 0
    n_chunks: int = 0            # prefill chunks run (recompute included)
    n_prefix_hit: int = 0        # context tokens served from the prefix
                                 # cache (skipped prefill entirely)

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        if self.n_generated <= 1:
            return 0.0
        return (self.finished - self.first_token) / (self.n_generated - 1)

    # --- TTFT decomposition (valid once first_token is set) ---
    @property
    def queue_wait(self) -> float:
        return (self.admitted or self.first_token) - self.arrival

    @property
    def prefill_span(self) -> float:
        if not self.prefill_start:
            return 0.0
        return (self.prefill_done or self.first_token) - self.prefill_start

    @property
    def decode_wait(self) -> float:
        if not self.prefill_done:
            return 0.0
        return self.first_token - self.prefill_done


def _pct(a: np.ndarray, q: float) -> float:
    return float(np.percentile(a, q)) if len(a) else 0.0


class VirtualClock:
    """A clock the caller advances explicitly.  Inject ``clock=vc.now``
    into :class:`SLOTracker` (and hand ``vc`` to the engine) and every
    latency metric becomes a deterministic function of the modeled step
    costs instead of host wall time."""

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float):
        assert dt >= 0.0, dt
        self.t += dt


class SLOTracker:
    def __init__(self, clock=None):
        self._clock = clock or time.perf_counter
        self.timings: dict[int, RequestTiming] = {}
        self.step_latencies: list[tuple[str, float]] = []
        self.compile_events: dict[str, list] = defaultdict(list)
        self.queue_depths: list[int] = []
        self.preemptions = 0
        self.stalls: list[tuple[str, float]] = []   # (kind, seconds)
        self.prefix_hit_tokens_total = 0
        # expert-pool paging counters (expert_pool_access)
        self.expert_pool_hits = 0
        self.expert_pool_misses = 0
        self.expert_pool_planned_hits = 0
        self._t0 = self._clock()

    def now(self) -> float:
        return self._clock() - self._t0

    def arrive(self, rid: int, n_prompt: int, at: float = None):
        """Record a request arrival, by default at ``now()``.  ``at``
        back-stamps a trace arrival time: in cluster replay a request
        reaches its replica when the router processes it, which may be
        after the trace arrival the SLO clock must measure from."""
        self.timings[rid] = RequestTiming(
            arrival=self.now() if at is None else at, n_prompt=n_prompt)

    def admitted(self, rid: int):
        # TTFT decomposition events freeze once the first token is out:
        # a mid-decode preemption re-admits and re-prefills (recompute),
        # and re-stamping would make decode_wait negative / queue_wait
        # exceed TTFT.  (n_chunks keeps counting — recompute work is
        # real work.)
        t = self.timings[rid]
        if t.first_token == 0.0:
            t.admitted = self.now()

    def prefill_started(self, rid: int):
        t = self.timings[rid]
        if t.prefill_start == 0.0:
            t.prefill_start = self.now()

    def chunk_done(self, rid: int):
        self.timings[rid].n_chunks += 1

    def prefix_hit(self, rid: int, n_tokens: int):
        """Record that ``n_tokens`` of a request's context were served
        from the prefix cache at admission (they skip prefill, so the
        TTFT prefill span covers only the suffix).  Called on EVERY
        admission of a cache-enabled engine, with 0 on a miss:
        pre-first-token re-stamps (preempt -> readmit) overwrite — the
        LAST admission is the one whose prefill span gates the first
        token, and a cold readmission must reset a stale hit mark;
        ``prefix_hit_tokens_total`` keeps counting every admission's
        savings (recompute avoided is real work avoided)."""
        self.prefix_hit_tokens_total += n_tokens
        t = self.timings[rid]
        if t.first_token == 0.0:
            t.n_prefix_hit = n_tokens

    def prefill_done(self, rid: int):
        # pre-first-token re-stamps are correct (a preempted-then-
        # recomputed prefill's LAST completion is what gates the first
        # token); post-first-token ones are recompute and are ignored
        t = self.timings[rid]
        if t.first_token == 0.0:
            t.prefill_done = self.now()

    def first_token(self, rid: int):
        t = self.timings[rid]
        if t.first_token == 0.0:
            t.first_token = self.now()
        t.n_generated += 1

    def token(self, rid: int):
        self.timings[rid].n_generated += 1

    def finish(self, rid: int):
        self.timings[rid].finished = self.now()

    def step(self, kind: str, seconds: float):
        self.step_latencies.append((kind, seconds))

    # ------------------------------------------------------------------
    # engine-health counters
    # ------------------------------------------------------------------
    def compiled(self, kind: str, key):
        """Record one step-function compile of the given kind ("decode" /
        "prefill" / "chunk" / "mixed") and shape signature."""
        self.compile_events[kind].append(key)

    def compile_count(self, kind: str) -> int:
        return len(self.compile_events.get(kind, []))

    @property
    def total_compiles(self) -> int:
        return sum(len(v) for v in self.compile_events.values())

    def queue_depth(self, depth: int):
        self.queue_depths.append(depth)

    def stall(self, kind: str, seconds: float):
        """Attribute ``seconds`` of decode stall to a prefill-carrying
        call of the given kind that ran while decode rows waited."""
        self.stalls.append((kind, seconds))

    def expert_pool_access(self, hits: int, misses: int,
                           planned_hits: int = 0, stall_s: float = 0.0):
        """Fold one engine call's expert-pool page accesses in: hits
        (page resident at access), misses (demand-fetched), and
        planned hits (the previous step's prefetch plan named the
        page — resident or not; the coverage numerator).  A non-zero
        ``stall_s`` attributes a decode step's demand-miss fetch wait
        (kind ``expert_miss``; the scheduler's residency gate records
        its own ``expert_gate`` stalls via :meth:`stall`)."""
        self.expert_pool_hits += int(hits)
        self.expert_pool_misses += int(misses)
        self.expert_pool_planned_hits += int(planned_hits)
        if stall_s > 0.0:
            self.stalls.append(("expert_miss", stall_s))

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        done = [t for t in self.timings.values() if t.finished > 0]
        if not done:
            return {"requests": 0}
        ttfts = np.array([t.ttft for t in done])
        tpots = np.array([t.tpot for t in done if t.n_generated > 1])
        total_tokens = sum(t.n_prompt + t.n_generated for t in done)
        wall = max(t.finished for t in done) - min(t.arrival for t in done)
        by_kind = defaultdict(list)
        for k, s in self.step_latencies:
            by_kind[k].append(s)
        dec = np.asarray(by_kind.get("decode", []))
        pre = np.asarray(by_kind.get("prefill", []))
        chk = np.asarray(by_kind.get("chunk", []))
        mix = np.asarray(by_kind.get("mixed", []))
        qd = np.asarray(self.queue_depths)
        stalls = np.asarray([s for _, s in self.stalls])
        xstalls = np.asarray([s for k, s in self.stalls
                              if k.startswith("expert")])
        pool_acc = self.expert_pool_hits + self.expert_pool_misses
        return {
            "requests": len(done),
            "ttft_mean": float(ttfts.mean()),
            "ttft_p50": _pct(ttfts, 50),
            "ttft_p90": _pct(ttfts, 90),
            "ttft_p99": _pct(ttfts, 99),
            # TTFT decomposition (chunk-level attribution)
            "ttft_queue_mean": float(np.mean([t.queue_wait for t in done])),
            "ttft_prefill_mean": float(
                np.mean([t.prefill_span for t in done])),
            "ttft_decode_wait_mean": float(
                np.mean([t.decode_wait for t in done])),
            "prefill_chunks": sum(t.n_chunks for t in done),
            # prefix-cache attribution: cached and cold TTFT separable
            "prefix_hit_tokens": self.prefix_hit_tokens_total,
            "prefix_hit_requests": sum(
                1 for t in done if t.n_prefix_hit > 0),
            "ttft_mean_hit": float(np.mean(
                [t.ttft for t in done if t.n_prefix_hit > 0]))
            if any(t.n_prefix_hit > 0 for t in done) else 0.0,
            "ttft_mean_cold": float(np.mean(
                [t.ttft for t in done if t.n_prefix_hit == 0]))
            if any(t.n_prefix_hit == 0 for t in done) else 0.0,
            "tpot_mean": float(tpots.mean()) if len(tpots) else 0.0,
            "tpot_p50": _pct(tpots, 50),
            "tpot_p90": _pct(tpots, 90),
            "tpot_p99": _pct(tpots, 99),
            "total_token_throughput": total_tokens / max(wall, 1e-9),
            "decode_steps": len(dec),
            "prefill_steps": len(pre),
            "chunk_steps": len(chk),
            "mixed_steps": len(mix),
            "decode_step_mean_s": float(dec.mean()) if len(dec) else 0.0,
            "decode_step_p50_s": _pct(dec, 50),
            "decode_step_p99_s": _pct(dec, 99),
            "prefill_step_p50_s": _pct(pre, 50),
            "prefill_step_p99_s": _pct(pre, 99),
            "chunk_step_p99_s": _pct(chk, 99),
            "mixed_step_p99_s": _pct(mix, 99),
            "decode_compiles": self.compile_count("decode"),
            "prefill_compiles": self.compile_count("prefill"),
            "chunk_compiles": self.compile_count("chunk"),
            "mixed_compiles": self.compile_count("mixed"),
            "total_compiles": self.total_compiles,
            "preemptions": self.preemptions,
            # decode-stall attribution
            "decode_stall_events": len(stalls),
            "decode_stall_total_s": float(stalls.sum()) if len(stalls)
            else 0.0,
            "decode_stall_max_s": float(stalls.max()) if len(stalls)
            else 0.0,
            # expert-pool paging attribution
            "expert_pool_hits": self.expert_pool_hits,
            "expert_pool_misses": self.expert_pool_misses,
            "expert_pool_hit_rate": (self.expert_pool_hits / pool_acc
                                     if pool_acc else 0.0),
            "expert_prefetch_coverage": (
                self.expert_pool_planned_hits / pool_acc
                if pool_acc else 0.0),
            "expert_stall_events": len(xstalls),
            "expert_stall_total_s": float(xstalls.sum())
            if len(xstalls) else 0.0,
            "expert_stall_max_s": float(xstalls.max())
            if len(xstalls) else 0.0,
            "queue_depth_mean": float(qd.mean()) if len(qd) else 0.0,
            "queue_depth_max": int(qd.max()) if len(qd) else 0,
        }


# ----------------------------------------------------------------------
# cluster rollups
# ----------------------------------------------------------------------


def aggregate_cluster_summary(trackers: list[SLOTracker]) -> dict:
    """Pool N replicas' trackers into one cluster-level summary.

    Request latencies (TTFT/TPOT) are pooled across replicas before
    taking percentiles — the cluster SLO is over *all* requests, not an
    average of per-replica percentiles.  Replica timelines are
    comparable because every replica's clock starts at the same trace
    origin (t=0 under a VirtualClock).  Also returns the per-replica
    summaries under ``"replicas"`` for imbalance diagnosis.
    """
    per = [t.summary() for t in trackers]
    done = [tm for t in trackers for tm in t.timings.values()
            if tm.finished > 0]
    if not done:
        return {"requests": 0, "replicas": per}
    ttfts = np.array([tm.ttft for tm in done])
    tpots = np.array([tm.tpot for tm in done if tm.n_generated > 1])
    total_tokens = sum(tm.n_prompt + tm.n_generated for tm in done)
    wall = max(tm.finished for tm in done) - \
        min(tm.arrival for tm in done)
    out = {
        "requests": len(done),
        "ttft_p50": _pct(ttfts, 50),
        "ttft_p90": _pct(ttfts, 90),
        "ttft_p99": _pct(ttfts, 99),
        "tpot_mean": float(tpots.mean()) if len(tpots) else 0.0,
        "tpot_p50": _pct(tpots, 50),
        "tpot_p90": _pct(tpots, 90),
        "tpot_p99": _pct(tpots, 99),
        "total_token_throughput": total_tokens / max(wall, 1e-9),
        "total_compiles": sum(s.get("total_compiles", 0) for s in per),
        "preemptions": sum(s.get("preemptions", 0) for s in per),
        "prefix_hit_tokens": sum(
            s.get("prefix_hit_tokens", 0) for s in per),
        "prefix_hit_requests": sum(
            s.get("prefix_hit_requests", 0) for s in per),
        "decode_steps": sum(s.get("decode_steps", 0) for s in per),
        "requests_per_replica": [s.get("requests", 0) for s in per],
        "replicas": per,
    }
    # expert-pool rollup: ratios recomputed from the pooled counts
    # (never averaged per-replica ratios)
    hits = sum(t.expert_pool_hits for t in trackers)
    misses = sum(t.expert_pool_misses for t in trackers)
    planned = sum(t.expert_pool_planned_hits for t in trackers)
    acc = hits + misses
    out["expert_pool_hits"] = hits
    out["expert_pool_misses"] = misses
    out["expert_pool_hit_rate"] = hits / acc if acc else 0.0
    out["expert_prefetch_coverage"] = planned / acc if acc else 0.0
    out["expert_stall_total_s"] = sum(
        s.get("expert_stall_total_s", 0.0) for s in per)
    out["expert_stall_events"] = sum(
        s.get("expert_stall_events", 0) for s in per)
    return out
