"""Latency/SLO bookkeeping: TTFT, TPOT, throughput, percentiles, and
engine-health counters (step-function compiles, preemptions, queue
depth).

The compile counter is the observable for batch bucketing: every time
the engine builds a step function for a new (kind, signature) pair it
calls :meth:`compiled`, so ``summary()["total_compiles"]`` counts XLA
tracings — the quantity power-of-two bucketing + wave prefill bound to
O(log max_batch + log max_len) regardless of trace length.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict

import numpy as np


@dataclasses.dataclass
class RequestTiming:
    arrival: float
    first_token: float = 0.0
    finished: float = 0.0
    n_prompt: int = 0
    n_generated: int = 0

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        if self.n_generated <= 1:
            return 0.0
        return (self.finished - self.first_token) / (self.n_generated - 1)


def _pct(a: np.ndarray, q: float) -> float:
    return float(np.percentile(a, q)) if len(a) else 0.0


class SLOTracker:
    def __init__(self):
        self.timings: dict[int, RequestTiming] = {}
        self.step_latencies: list[tuple[str, float]] = []
        self.compile_events: dict[str, list] = defaultdict(list)
        self.queue_depths: list[int] = []
        self.preemptions = 0
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def arrive(self, rid: int, n_prompt: int):
        self.timings[rid] = RequestTiming(arrival=self.now(),
                                          n_prompt=n_prompt)

    def first_token(self, rid: int):
        t = self.timings[rid]
        if t.first_token == 0.0:
            t.first_token = self.now()
        t.n_generated += 1

    def token(self, rid: int):
        self.timings[rid].n_generated += 1

    def finish(self, rid: int):
        self.timings[rid].finished = self.now()

    def step(self, kind: str, seconds: float):
        self.step_latencies.append((kind, seconds))

    # ------------------------------------------------------------------
    # engine-health counters
    # ------------------------------------------------------------------
    def compiled(self, kind: str, key):
        """Record one step-function compile of the given kind ("decode" /
        "prefill") and shape signature (e.g. the batch bucket)."""
        self.compile_events[kind].append(key)

    def compile_count(self, kind: str) -> int:
        return len(self.compile_events.get(kind, []))

    @property
    def total_compiles(self) -> int:
        return sum(len(v) for v in self.compile_events.values())

    def queue_depth(self, depth: int):
        self.queue_depths.append(depth)

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        done = [t for t in self.timings.values() if t.finished > 0]
        if not done:
            return {"requests": 0}
        ttfts = np.array([t.ttft for t in done])
        tpots = np.array([t.tpot for t in done if t.n_generated > 1])
        total_tokens = sum(t.n_prompt + t.n_generated for t in done)
        wall = max(t.finished for t in done) - min(t.arrival for t in done)
        by_kind = defaultdict(list)
        for k, s in self.step_latencies:
            by_kind[k].append(s)
        dec = np.asarray(by_kind.get("decode", []))
        pre = np.asarray(by_kind.get("prefill", []))
        qd = np.asarray(self.queue_depths)
        return {
            "requests": len(done),
            "ttft_mean": float(ttfts.mean()),
            "ttft_p50": _pct(ttfts, 50),
            "ttft_p90": _pct(ttfts, 90),
            "ttft_p99": _pct(ttfts, 99),
            "tpot_mean": float(tpots.mean()) if len(tpots) else 0.0,
            "tpot_p50": _pct(tpots, 50),
            "tpot_p90": _pct(tpots, 90),
            "tpot_p99": _pct(tpots, 99),
            "total_token_throughput": total_tokens / max(wall, 1e-9),
            "decode_steps": len(dec),
            "prefill_steps": len(pre),
            "decode_step_mean_s": float(dec.mean()) if len(dec) else 0.0,
            "decode_step_p50_s": _pct(dec, 50),
            "decode_step_p99_s": _pct(dec, 99),
            "prefill_step_p50_s": _pct(pre, 50),
            "prefill_step_p99_s": _pct(pre, 99),
            "decode_compiles": self.compile_count("decode"),
            "prefill_compiles": self.compile_count("prefill"),
            "total_compiles": self.total_compiles,
            "preemptions": self.preemptions,
            "queue_depth_mean": float(qd.mean()) if len(qd) else 0.0,
            "queue_depth_max": int(qd.max()) if len(qd) else 0,
        }
