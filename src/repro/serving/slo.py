"""Latency/SLO bookkeeping: TTFT, TPOT, throughput, percentiles."""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict

import numpy as np


@dataclasses.dataclass
class RequestTiming:
    arrival: float
    first_token: float = 0.0
    finished: float = 0.0
    n_prompt: int = 0
    n_generated: int = 0

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        if self.n_generated <= 1:
            return 0.0
        return (self.finished - self.first_token) / (self.n_generated - 1)


class SLOTracker:
    def __init__(self):
        self.timings: dict[int, RequestTiming] = {}
        self.step_latencies: list[tuple[str, float]] = []
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def arrive(self, rid: int, n_prompt: int):
        self.timings[rid] = RequestTiming(arrival=self.now(),
                                          n_prompt=n_prompt)

    def first_token(self, rid: int):
        t = self.timings[rid]
        if t.first_token == 0.0:
            t.first_token = self.now()
        t.n_generated += 1

    def token(self, rid: int):
        self.timings[rid].n_generated += 1

    def finish(self, rid: int):
        self.timings[rid].finished = self.now()

    def step(self, kind: str, seconds: float):
        self.step_latencies.append((kind, seconds))

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        done = [t for t in self.timings.values() if t.finished > 0]
        if not done:
            return {"requests": 0}
        ttfts = np.array([t.ttft for t in done])
        tpots = np.array([t.tpot for t in done if t.n_generated > 1])
        total_tokens = sum(t.n_prompt + t.n_generated for t in done)
        wall = max(t.finished for t in done) - min(t.arrival for t in done)
        by_kind = defaultdict(list)
        for k, s in self.step_latencies:
            by_kind[k].append(s)
        return {
            "requests": len(done),
            "ttft_mean": float(ttfts.mean()),
            "ttft_p99": float(np.percentile(ttfts, 99)),
            "tpot_mean": float(tpots.mean()) if len(tpots) else 0.0,
            "tpot_p99": (float(np.percentile(tpots, 99))
                         if len(tpots) else 0.0),
            "total_token_throughput": total_tokens / max(wall, 1e-9),
            "decode_steps": len(by_kind.get("decode", [])),
            "prefill_steps": len(by_kind.get("prefill", [])),
            "decode_step_mean_s": (float(np.mean(by_kind["decode"]))
                                   if by_kind.get("decode") else 0.0),
        }
