"""Multi-replica serving: N ``ServingEngine`` replicas behind a router,
with one shared EPLB placement (paper Fig. 9–12 scale).

The paper's cluster deployment co-locates prefill and decode on every
replica, keeps ONE EPLB expert placement/replication substrate for the
whole fleet (recomputed from aggregate load on a common rebalance
window), and lets each replica route tokens per phase (METRO decode /
EPLB prefill).  This module reproduces that shape on simulated
replicas:

  * **Router** — ``dispatch="rr"`` round-robin, ``dispatch="low"``
    least-outstanding-work (queued + active tokens remaining, the
    natural unit for a token-serving fleet), or ``dispatch="prefix"``
    prefix-affinity: the replica whose radix prefix index holds the
    longest cached match of the prompt wins (cache reuse beats load
    balance when a match exists), falling back to least-outstanding
    work.  Deterministic: ties break toward the lowest replica id.
  * **Shared placement** — per-replica expert-load EWMAs are aggregated
    (:func:`repro.core.placement.aggregate_expert_loads`) into one
    cluster signal; one :func:`build_placement` runs; every replica
    reshuffles its physical expert weights to the SAME placement.
    Replica choice moves compute, not math, so the reshuffle is bitwise
    invisible to in-flight requests (pinned by the mid-prefill
    rebalance regression test) — the fleet can reshuffle on a common
    window without draining.
  * **Virtual time** — pass ``step_cost`` and every replica runs on its
    own :class:`~repro.serving.slo.VirtualClock` advanced by the
    modeled cost of each step (decode cost driven by ``max_activated``,
    the paper's memory-bound quantity).  Replica timelines are
    independent — N replicas genuinely serve in parallel — and every
    latency percentile is bit-reproducible on CPU, which is what lets
    ``benchmarks/bench_pareto_slo.py`` binary-search arrival rates.
  * **Compile sharing** — replicas are identical configs, so they share
    one step-function cache: N replicas compile each shape signature
    once, not N times.

A single-replica cluster is *exactly* a bare engine: same tokens, same
per-call expert_hist (tests/test_cluster.py pins this for METRO and
EPLB) — the cluster layer adds dispatch and placement sharing, never
numerics.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import aggregate_expert_loads, build_placement
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.slo import VirtualClock, aggregate_cluster_summary
from repro.serving.traffic import SyntheticRequest
from repro.sharding.policy import Dist


@dataclasses.dataclass
class ClusterConfig:
    num_replicas: int = 2
    dispatch: str = "low"       # "low" (least outstanding work) | "rr"
                                # | "prefix" (longest cached prefix
                                # match wins; falls back to "low")
    rebalance_every: int = 0    # cluster-wide decode steps between shared
                                # EPLB reshuffles (0 = never)


def default_step_cost(kind: str, n_tokens: int, stats: dict) -> float:
    """Deterministic per-call cost model for virtual-time simulation.

    Decode is the memory-bound phase: per-step latency is dominated by
    streaming the *activated* expert weights from HBM, so the model
    charges the per-device max activated-expert count the step actually
    produced (``stats["max_activated"]``) — exactly the quantity METRO
    minimizes, so the METRO-vs-EPLB gap the Pareto harness measures
    comes from the routing algorithms' real activation decisions, not
    from an assumed constant.  Prefill-carrying calls are modeled
    compute-bound: cost scales with the tokens processed.

    Units are virtual seconds; absolute scale is arbitrary (only
    METRO/EPLB and rate-sweep *comparisons* are claims), chosen so a
    reduced-model replica saturates at O(1e2–1e3) req/s.
    """
    if kind == "decode":
        return 2e-4 + 1.5e-4 * stats["max_activated"] + 1e-5 * n_tokens
    return 2e-4 + 2e-5 * n_tokens


class ClusterEngine:
    def __init__(self, cfg: ModelConfig, dist: Dist, params,
                 ecfg: EngineConfig, ccfg: ClusterConfig,
                 step_cost: Optional[Callable] = default_step_cost,
                 routing_table_width: int = 0,
                 fn_cache: Optional[dict] = None):
        assert ccfg.num_replicas >= 1
        assert ccfg.dispatch in ("low", "rr", "prefix"), ccfg.dispatch
        self.cfg, self.dist = cfg, dist
        self.ccfg = ccfg
        self.step_cost = step_cost
        # the cluster owns the rebalance window; replicas never
        # rebalance locally (they would diverge from the shared
        # placement between windows)
        recfg = dataclasses.replace(ecfg, rebalance_every=0)
        # one jit cache for the whole fleet (identical configs); an
        # external cache may be passed to reuse compiles across
        # clusters of the same config (the Pareto sweep's rate probes)
        if fn_cache is None:
            fn_cache = {"decode": {}, "prefill": {}, "chunk": {},
                        "mixed": {}}
        self.replicas: list[ServingEngine] = []
        for _ in range(ccfg.num_replicas):
            # fresh pytree containers per replica (leaves shared):
            # rebalance swaps leaves in-place per replica, and replicas
            # must be able to hold different physical layouts between
            # cluster windows without aliasing each other
            p_i = jax.tree.map(lambda a: a, params)
            clock = VirtualClock() if step_cost is not None else None
            self.replicas.append(ServingEngine(
                cfg, dist, p_i, recfg, routing_table_width,
                clock=clock, step_cost=step_cost, fn_cache=fn_cache))
        self._rr = 0
        self._rid_map: dict[int, tuple[int, int]] = {}
        self._next_crid = 0
        self._rebalances = 0
        self._last_window = 0
        self.steps = 0

    # ------------------------------------------------------------------
    # router
    # ------------------------------------------------------------------
    def _pick_replica(self, prompt=None) -> int:
        if self.ccfg.dispatch == "rr":
            i = self._rr % len(self.replicas)
            self._rr += 1
            return i
        if self.ccfg.dispatch == "prefix" and prompt is not None:
            # prefix affinity: the replica whose radix index holds the
            # longest cached prefix of this prompt serves it — reuse
            # beats balance when a match exists (the skipped prefill is
            # work no other replica can avoid).  Ties, and the no-match
            # case, fall back to least outstanding work; all ties break
            # to the lowest replica id (deterministic).
            matches = [r.prefix_match_len(prompt) for r in self.replicas]
            best = max(matches)
            if best > 0:
                cand = [i for i, m in enumerate(matches) if m == best]
                return min(cand, key=lambda i: (
                    self.replicas[i].state.outstanding_tokens(), i))
        # least outstanding work; deterministic tie-break on replica id
        return int(np.argmin([r.state.outstanding_tokens()
                              for r in self.replicas]))

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival: Optional[float] = None) -> int:
        ri = self._pick_replica(prompt)
        rep = self.replicas[ri]
        if arrival is not None and not rep.has_work:
            # an idle server starts working when the request arrives
            rep.advance_clock_to(arrival)
        lrid = rep.submit(prompt, max_new_tokens, arrival=arrival)
        crid = self._next_crid
        self._next_crid += 1
        self._rid_map[crid] = (ri, lrid)
        return crid

    # ------------------------------------------------------------------
    # aggregate views
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return any(r.has_work for r in self.replicas)

    @property
    def completed(self):
        """Completed requests keyed by *cluster* rid."""
        out = {}
        for crid, (ri, lrid) in self._rid_map.items():
            r = self.replicas[ri].completed.get(lrid)
            if r is not None:
                out[crid] = r
        return out

    @property
    def rebalances(self) -> int:
        return self._rebalances

    def replica_of(self, crid: int) -> int:
        return self._rid_map[crid][0]

    def summary(self) -> dict:
        s = aggregate_cluster_summary([r.slo for r in self.replicas])
        s["cluster_rebalances"] = self._rebalances
        return s

    # ------------------------------------------------------------------
    # shared EPLB placement
    # ------------------------------------------------------------------
    def rebalance(self):
        """Aggregate every replica's expert-load EWMA, compute ONE EPLB
        placement from the cluster-wide signal, and reshuffle every
        replica's physical weights to it (the common window)."""
        if not self.cfg.is_moe:
            return
        loads = aggregate_expert_loads(
            [r.expert_loads for r in self.replicas])
        placement = build_placement(
            self.cfg.num_experts, self.dist.ep_size,
            self.dist.slots_per_device, loads=loads)
        for r in self.replicas:
            r.rebalance(placement=placement)
        self._rebalances += 1

    def _maybe_rebalance(self):
        every = self.ccfg.rebalance_every
        if not every or not self.cfg.is_moe:
            return
        total = sum(r.decode_steps for r in self.replicas)
        if total // every > self._last_window:
            self._last_window = total // every
            self.rebalance()

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def step(self):
        """One cluster round: every replica with work runs one engine
        iteration (replicas serve in parallel — under virtual time each
        advances its own clock)."""
        for r in self.replicas:
            if r.has_work:
                r.step()
        self.steps += 1
        self._maybe_rebalance()

    def run(self, max_iters: int = 100_000) -> dict:
        it = 0
        while self.has_work and it < max_iters:
            self.step()
            it += 1
        return self.summary()

    # ------------------------------------------------------------------
    # open-loop replay (the Pareto harness's load loop)
    # ------------------------------------------------------------------
    def replay_open_loop(self, trace: list[SyntheticRequest], *,
                         max_iters: int = 200_000,
                         on_iteration: Optional[Callable] = None) -> dict:
        """Submit each trace request at its arrival time and step the
        cluster in between (virtual time only — for wall-clock single-
        engine replay use :func:`repro.serving.traffic.replay_open_loop`).

        The global frontier is the slowest *busy* replica's clock: a
        request is dispatched once every busy replica has reached its
        arrival (so no replica observes an arrival from its own
        future), idle replicas jump forward to the arrival, and TTFT
        is measured from the back-stamped trace arrival.  The frontier
        is recomputed after every submit — a submit can wake an idle
        replica at the arrival time, which may become the new minimum,
        and later arrivals must not land on a replica whose clock is
        still behind them.

        ``on_iteration(cluster)`` runs after every loop iteration — a
        gauge hook (e.g. the prefix benchmark's pages-in-use peak) so
        callers never have to clone this frontier logic.
        """
        assert self.step_cost is not None, (
            "cluster replay_open_loop needs the virtual-time cost "
            "model (step_cost); wall-clock open-loop replay is the "
            "single-engine repro.serving.traffic.replay_open_loop")
        i, it = 0, 0
        while (i < len(trace) or self.has_work) and it < max_iters:
            while i < len(trace):
                busy = [r for r in self.replicas if r.has_work]
                t = (min(r._vclock.t for r in busy) if busy
                     else trace[i].arrival)
                if trace[i].arrival > t:
                    break
                self.submit(trace[i].prompt, trace[i].max_new_tokens,
                            arrival=trace[i].arrival)
                i += 1
            if self.has_work:
                self.step()
            if on_iteration is not None:
                on_iteration(self)
            it += 1
        return self.summary()
