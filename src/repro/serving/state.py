"""Serving-engine state: requests, slot/page residency, admission queue.

This is the bottom layer of the serving stack (state -> scheduler ->
executor -> engine façade -> cluster): pure host-side bookkeeping with
no policy and no jax.  The scheduler decides *what* to admit, preempt
or run; the :class:`EngineState` records *who* holds which slot and
which KV pages, which requests are waiting / active / completed, and
the expert-load EWMA that drives EPLB rebalancing.

With the prefix cache enabled the state also owns the
:class:`~repro.serving.prefix.RadixPrefixIndex`: :meth:`activate` maps
a match's shared pages (and stages the copy-on-write boundary page) and
:meth:`retire` feeds the finished request's prefilled prefix back into
the index before its pages are released — so the pages survive,
refcounted, for the next request that shares them.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serving.kv import PagedKVManager, pages_for
from repro.serving.prefix import PrefixMatch, RadixPrefixIndex


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [n] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0                # next position to fill
    n_ctx: int = 0              # context tokens to prefill (this admission)
    done: bool = False
    preempted: int = 0          # times evicted under page pressure
    preempted_in_prefill: int = 0   # of those, evictions between chunks
    admit_pos: int = 0          # pos at admission (prefix-hit start)
    prefix_hit_tokens: int = 0  # cached tokens skipped (this admission)

    def context_tokens(self) -> np.ndarray:
        """Tokens to (re)prefill: the prompt plus anything generated
        before a preemption (recompute-on-readmission)."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def prefilling(self) -> bool:
        return self.pos < self.n_ctx

    def remaining_tokens(self) -> int:
        """Outstanding work estimate: context still to prefill plus
        tokens still to generate (the router's load unit)."""
        return max(self.n_ctx - self.pos, 0) + \
            max(self.max_new_tokens - len(self.generated), 0)


class EngineState:
    """Mutable serving state shared by scheduler and engine façade."""

    # per-call expert_hist log (equivalence tests); bounded so a
    # long-running engine doesn't grow it without limit
    HIST_LOG_CAP = 8192

    def __init__(self, ecfg, num_experts: int,
                 prefix_enabled: bool = False):
        self.ecfg = ecfg
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.completed: dict[int, Request] = {}
        self.free_slots = list(range(ecfg.max_batch))
        self.next_rid = 0
        self.decode_steps = 0
        self.expert_loads = np.ones(max(num_experts, 1))
        self.expert_hist_log: list[np.ndarray] = []
        if ecfg.kv_layout == "paged":
            pmax = pages_for(ecfg.max_len, ecfg.page_size)
            num_pages = ecfg.num_pages or ecfg.max_batch * pmax
            self.kvman: Optional[PagedKVManager] = PagedKVManager(
                num_pages=num_pages, page_size=ecfg.page_size,
                max_pages_per_seq=pmax, max_seqs=ecfg.max_batch)
        else:
            self.kvman = None
        self.prefix: Optional[RadixPrefixIndex] = (
            RadixPrefixIndex(self.kvman, ecfg.page_size)
            if prefix_enabled and self.kvman is not None else None)

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    def prefills_in_flight(self) -> int:
        """Active requests whose chunked prefill has not finished."""
        return sum(1 for r in self.active.values() if r.prefilling)

    def outstanding_tokens(self) -> int:
        """Total outstanding work (queued + active), the quantity the
        cluster's least-outstanding-work dispatch balances on."""
        work = sum(len(r.context_tokens())
                   + max(r.max_new_tokens - len(r.generated), 0)
                   for r in self.queue)
        work += sum(r.remaining_tokens() for r in self.active.values())
        return work

    # ------------------------------------------------------------------
    def new_request(self, prompt: np.ndarray, max_new_tokens: int
                    ) -> Request:
        prompt = np.asarray(prompt, np.int32)
        assert len(prompt) < self.ecfg.max_len, (
            f"prompt of {len(prompt)} tokens exceeds max_len-1="
            f"{self.ecfg.max_len - 1}")
        r = Request(self.next_rid, prompt, max_new_tokens)
        self.next_rid += 1
        self.queue.append(r)
        return r

    def activate(self, r: Request, n_ctx: int, first_target: int,
                 match: Optional[PrefixMatch] = None
                 ) -> Optional[tuple[int, int]]:
        """Give ``r`` a slot and pages covering ``first_target`` tokens
        (the scheduler verified the budget).

        With a prefix ``match``: the matched full pages are mapped
        shared (read-only — prefill starts at ``match.m``, above them),
        the copy-on-write source is pinned, any shortfall beyond the
        free list is reclaimed from the index (the match's own pages
        are already refcounted, so reclaim can never touch them), and
        the freshly-allocated boundary page is returned as a
        ``(src, dst, keep)`` device-copy (keep = matched tokens inside
        the boundary page) the scheduler must run — and unpin — before
        the request's first step."""
        r.slot = self.free_slots.pop()
        r.n_ctx = n_ctx
        r.pos = 0
        r.admit_pos = 0
        r.prefix_hit_tokens = 0
        cow: Optional[tuple[int, int]] = None
        if self.kvman is not None:
            if match is not None and match.hit:
                r.pos = r.admit_pos = match.m
                r.prefix_hit_tokens = match.m
                self.kvman.map_shared(r.slot, match.pages)
                if match.cow_src is not None:
                    self.kvman.pin(match.cow_src)
            need = pages_for(first_target, self.ecfg.page_size) \
                - self.kvman.owned(r.slot)
            short = need - self.kvman.num_free
            if short > 0 and self.prefix is not None:
                self.prefix.reclaim(short)
            ok = self.kvman.ensure(r.slot, first_target)
            assert ok, "admission page reservation failed"
            if match is not None and match.cow_src is not None:
                dst = int(self.kvman.page_table[r.slot, len(match.pages)])
                keep = match.m - len(match.pages) * self.ecfg.page_size
                cow = (int(match.cow_src), dst, keep)
        self.active[r.rid] = r
        return cow

    def retire(self, r: Request):
        """Release a finished request's slot and pages — after feeding
        its prefilled prefix to the prefix index (content-deduplicated;
        the indexed pages survive the release, refcounted)."""
        r.done = True
        if self.prefix is not None and r.n_ctx > 0:
            npg = pages_for(r.n_ctx, self.ecfg.page_size)
            pages = [int(self.kvman.page_table[r.slot, i])
                     for i in range(npg)]
            self.prefix.insert(r.context_tokens()[:r.n_ctx], pages)
        self.free_slots.append(r.slot)
        if self.kvman is not None:
            self.kvman.release(r.slot)
        self.completed[r.rid] = r
        del self.active[r.rid]

    def evict(self, v: Request):
        """Requeue a preempted request for recompute-on-readmission.
        Shared prefix pages just drop one reference; the victim's
        private (suffix / copy-on-write) pages go back to the pool."""
        if v.prefilling:
            v.preempted_in_prefill += 1
        self.kvman.release(v.slot)
        self.free_slots.append(v.slot)
        del self.active[v.rid]
        v.slot, v.pos, v.n_ctx, v.preempted = -1, 0, 0, v.preempted + 1
        v.admit_pos, v.prefix_hit_tokens = 0, 0
        self.queue.appendleft(v)

    # ------------------------------------------------------------------
    def record_hist(self, hist: np.ndarray, ewma: float):
        """Log one step's per-expert token histogram and fold it into
        the expert-load EWMA (the rebalance signal)."""
        self.expert_hist_log.append(hist)
        if len(self.expert_hist_log) > self.HIST_LOG_CAP:
            del self.expert_hist_log[:self.HIST_LOG_CAP // 2]
        self.expert_loads = ewma * self.expert_loads + \
            (1 - ewma) * (hist + 1e-3)
