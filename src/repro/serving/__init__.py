from repro.serving.engine import ServingEngine, EngineConfig, Request
from repro.serving.kv import PagedKVManager, pages_for
from repro.serving.slo import SLOTracker
from repro.serving.traffic import (SyntheticRequest, TrafficConfig,
                                   generate_trace, replay_closed_loop,
                                   replay_open_loop)

__all__ = ["ServingEngine", "EngineConfig", "Request", "SLOTracker",
           "PagedKVManager", "pages_for", "TrafficConfig",
           "SyntheticRequest", "generate_trace", "replay_open_loop",
           "replay_closed_loop"]
