from repro.serving.engine import ServingEngine, EngineConfig
from repro.serving.state import Request, EngineState
from repro.serving.scheduler import Scheduler
from repro.serving.executor import Executor
from repro.serving.cluster import (ClusterConfig, ClusterEngine,
                                   default_step_cost)
from repro.serving.kv import PagedKVManager, pages_for
from repro.serving.expert_pool import (ExpertPagePool, build_expert_pool,
                                       expert_page_bytes, moe_layer_count)
from repro.serving.prefix import PrefixMatch, RadixPrefixIndex
from repro.serving.slo import (SLOTracker, VirtualClock,
                               aggregate_cluster_summary)
from repro.serving.traffic import (SyntheticRequest, TrafficConfig,
                                   generate_trace, replay_closed_loop,
                                   replay_open_loop,
                                   spawn_traffic_configs)

__all__ = ["ServingEngine", "EngineConfig", "Request", "EngineState",
           "Scheduler", "Executor", "ClusterConfig", "ClusterEngine",
           "default_step_cost", "SLOTracker", "VirtualClock",
           "aggregate_cluster_summary", "PagedKVManager", "pages_for",
           "ExpertPagePool", "build_expert_pool", "expert_page_bytes",
           "moe_layer_count",
           "PrefixMatch", "RadixPrefixIndex",
           "TrafficConfig", "SyntheticRequest", "generate_trace",
           "replay_open_loop", "replay_closed_loop",
           "spawn_traffic_configs"]
