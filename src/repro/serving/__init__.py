from repro.serving.engine import ServingEngine, EngineConfig, Request
from repro.serving.slo import SLOTracker

__all__ = ["ServingEngine", "EngineConfig", "Request", "SLOTracker"]
