"""Paged KV-cache manager: fixed-size pages + a free-list allocator.

The dense per-slot cache (``[max_batch, max_len]``) forces
``max_batch * max_len`` tokens of KV residency whether or not the slots
are full — the engine's batch size is then bounded by *worst-case*
sequence length.  Paging (vLLM's PagedAttention scheme) breaks that
coupling: the device holds one flat pool of ``num_pages`` fixed-size
pages shared by all sequences, and each sequence owns only the pages its
tokens actually occupy, tracked in a host-side page table.

The manager here is pure host-side numpy bookkeeping:

  * a LIFO free list of physical page ids (O(1) alloc/free, and recently
    freed pages are reused first — friendlier to any HBM-side locality),
  * a ``[max_seqs, max_pages_per_seq]`` int32 page table, ``-1`` = hole.
    Rows are step inputs to the jitted decode/prefill functions (data,
    never compile-time constants, so growth never recompiles),
  * incremental growth: ``ensure(slot, length)`` allocates just the
    pages needed to cover ``length`` tokens; the engine preempts a
    victim sequence when the pool runs dry.

Device-side page pools live in the model cache pytree with layout
``[num_pages, page_size, kv_heads, head_dim]`` per attention layer —
chosen so that (page, offset) flattens to a single linear token index,
making every read a 1-gather and every write a 1-scatter
(see ``models/layers.attention_decode_paged``), and so the Pallas paged
kernel can map grid block -> physical page via scalar-prefetched tables
(``kernels/flash_decode.flash_decode_paged``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def pages_for(length: int, page_size: int) -> int:
    """Pages needed to hold ``length`` tokens."""
    return -(-int(length) // page_size)


@dataclasses.dataclass
class PagedKVManager:
    """Free-list page allocator + per-slot page tables (host side)."""

    num_pages: int
    page_size: int
    max_pages_per_seq: int
    max_seqs: int

    def __post_init__(self):
        assert self.num_pages >= 1 and self.page_size >= 1
        # a lone sequence must always be able to grow to its max length
        # (the engine preempts everyone else, but never the grower)
        assert self.num_pages >= self.max_pages_per_seq, (
            f"pool of {self.num_pages} pages cannot hold one full "
            f"sequence of {self.max_pages_per_seq} pages")
        self._free: list[int] = list(range(self.num_pages - 1, -1, -1))
        self.page_table = np.full(
            (self.max_seqs, self.max_pages_per_seq), -1, np.int32)
        self._owned = np.zeros(self.max_seqs, np.int32)  # pages per slot

    # ------------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def owned(self, slot: int) -> int:
        return int(self._owned[slot])

    # ------------------------------------------------------------------
    def ensure(self, slot: int, length: int) -> bool:
        """Grow slot's table to cover ``length`` tokens.  Returns False
        (allocating nothing) if the free list can't cover the growth."""
        want = pages_for(length, self.page_size)
        if want > self.max_pages_per_seq:
            raise ValueError(
                f"sequence of {length} tokens needs {want} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        have = self.owned(slot)
        need = want - have
        if need <= 0:
            return True
        if need > len(self._free):
            return False
        for i in range(have, want):
            self.page_table[slot, i] = self._free.pop()
        self._owned[slot] = want
        return True

    def release(self, slot: int) -> int:
        """Free every page owned by ``slot``; returns the count freed."""
        n = self.owned(slot)
        for i in range(n):
            self._free.append(int(self.page_table[slot, i]))
            self.page_table[slot, i] = -1
        self._owned[slot] = 0
        return n

    # ------------------------------------------------------------------
    def rows(self, slots: np.ndarray) -> np.ndarray:
        """Page-table rows for a batch of slots (copy; safe to mutate)."""
        return self.page_table[np.asarray(slots, np.int64)].copy()

    # ------------------------------------------------------------------
    # invariants (used by the preemption/chunking regression tests)
    # ------------------------------------------------------------------
    def mapped_pages(self) -> np.ndarray:
        """Sorted physical ids of every currently-mapped page."""
        return np.sort(self.page_table[self.page_table >= 0])

    def check_consistent(self):
        """Assert the allocator invariants: no physical page is mapped
        twice (chunk-resume must never double-write a page), the free
        list is disjoint from the mapped set, and together they cover
        the pool exactly."""
        mapped = self.mapped_pages()
        assert len(mapped) == len(np.unique(mapped)), \
            "a physical page is mapped by two table entries"
        free = np.asarray(self._free, np.int64)
        assert len(np.intersect1d(mapped, free)) == 0, \
            "a free page is still mapped"
        assert len(mapped) + len(free) == self.num_pages, \
            "pages leaked: mapped + free != pool"
        assert int(self._owned.sum()) == len(mapped), \
            "per-slot owned counts disagree with the table"
