"""Paged KV-cache manager: fixed-size pages, a free-list allocator, and
reference-counted sharing for the prefix cache.

The dense per-slot cache (``[max_batch, max_len]``) forces
``max_batch * max_len`` tokens of KV residency whether or not the slots
are full — the engine's batch size is then bounded by *worst-case*
sequence length.  Paging (vLLM's PagedAttention scheme) breaks that
coupling: the device holds one flat pool of ``num_pages`` fixed-size
pages shared by all sequences, and each sequence owns only the pages its
tokens actually occupy, tracked in a host-side page table.

The manager here is pure host-side numpy bookkeeping:

  * a LIFO free list of physical page ids (O(1) alloc/free, and recently
    freed pages are reused first — friendlier to any HBM-side locality),
  * a ``[max_seqs, max_pages_per_seq]`` int32 page table, ``-1`` = hole.
    Rows are step inputs to the jitted decode/prefill functions (data,
    never compile-time constants, so growth never recompiles),
  * incremental growth: ``ensure(slot, length)`` allocates just the
    pages needed to cover ``length`` tokens; the engine preempts a
    victim sequence when the pool runs dry.

**Shared-prefix extensions** (``serving/prefix.py`` builds on these):

  * ``refcount[p]`` counts the page-table entries mapping physical page
    ``p`` — :meth:`map_shared` maps an existing page into a second (or
    third, …) slot's table, so requests with a common prompt prefix
    read ONE physical copy.  Shared pages are read-only by convention:
    a request only ever writes positions >= its own prefill start, and
    admission maps shared pages strictly below that point (the
    partially-filled boundary page is **copied**, never shared — the
    copy-on-write step the scheduler drives via
    :meth:`Executor.run_copy_pages`).
  * ``indexed[p]`` marks pages retained by the radix prefix index after
    their last sequence released them (cached, reclaimable).  A page
    returns to the free list only when it is neither table-referenced,
    indexed, nor pinned.
  * ``pin``/``unpin`` hold a page alive across the admission window
    between matching a copy-on-write source and completing the device
    copy (eviction during that window would hand the source page to the
    very allocation that wants to copy from it).

Device-side page pools live in the model cache pytree with layout
``[num_pages, page_size, kv_heads, head_dim]`` per attention layer —
chosen so that (page, offset) flattens to a single linear token index,
making every read a 1-gather and every write a 1-scatter
(see ``models/layers.attention_decode_paged``), and so the Pallas paged
kernel can map grid block -> physical page via scalar-prefetched tables
(``kernels/flash_decode.flash_decode_paged``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def pages_for(length: int, page_size: int) -> int:
    """Pages needed to hold ``length`` tokens."""
    return -(-int(length) // page_size)


@dataclasses.dataclass
class PagedKVManager:
    """Free-list page allocator + per-slot page tables (host side)."""

    num_pages: int
    page_size: int
    max_pages_per_seq: int
    max_seqs: int

    def __post_init__(self):
        assert self.num_pages >= 1 and self.page_size >= 1
        # a lone sequence must always be able to grow to its max length
        # (the engine preempts everyone else, but never the grower)
        assert self.num_pages >= self.max_pages_per_seq, (
            f"pool of {self.num_pages} pages cannot hold one full "
            f"sequence of {self.max_pages_per_seq} pages")
        self._free: list[int] = list(range(self.num_pages - 1, -1, -1))
        self.page_table = np.full(
            (self.max_seqs, self.max_pages_per_seq), -1, np.int32)
        self._owned = np.zeros(self.max_seqs, np.int32)  # pages per slot
        # --- sharing state (prefix cache) ---
        self.refcount = np.zeros(self.num_pages, np.int32)  # table refs
        self.indexed = np.zeros(self.num_pages, bool)   # prefix-index held
        self._pins = np.zeros(self.num_pages, np.int32)  # CoW-copy guards
        # --- counters (benchmark observables) ---
        self.alloc_count = 0        # pages popped from the free list
        self.shared_count = 0       # table entries satisfied by sharing

    # ------------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Pages not on the free list (table-referenced, index-cached,
        or pinned)."""
        return self.num_pages - len(self._free)

    @property
    def num_reclaimable(self) -> int:
        """Pages held ONLY by the prefix index — evicting their nodes
        (leaf-first, see ``RadixPrefixIndex.reclaim``) returns exactly
        these pages to the free list.  The page-aware admission policy
        budgets against ``num_free + num_reclaimable``."""
        return int((self.indexed & (self.refcount == 0)
                    & (self._pins == 0)).sum())

    def owned(self, slot: int) -> int:
        return int(self._owned[slot])

    # ------------------------------------------------------------------
    # refcount plumbing
    # ------------------------------------------------------------------
    def _maybe_free(self, p: int):
        if (self.refcount[p] == 0 and not self.indexed[p]
                and self._pins[p] == 0):
            self._free.append(p)

    def ensure(self, slot: int, length: int) -> bool:
        """Grow slot's table to cover ``length`` tokens.  Returns False
        (allocating nothing) if the free list can't cover the growth."""
        want = pages_for(length, self.page_size)
        if want > self.max_pages_per_seq:
            raise ValueError(
                f"sequence of {length} tokens needs {want} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        have = self.owned(slot)
        need = want - have
        if need <= 0:
            return True
        if need > len(self._free):
            return False
        for i in range(have, want):
            p = self._free.pop()
            self.page_table[slot, i] = p
            self.refcount[p] += 1
            self.alloc_count += 1
        self._owned[slot] = want
        return True

    def map_shared(self, slot: int, pages: list[int]):
        """Map existing physical ``pages`` (a cached prefix, in logical
        order) into the *empty* table of ``slot``, bumping refcounts.
        Shared pages are read-only for this slot: the scheduler maps
        only pages strictly below the request's prefill start."""
        assert self.owned(slot) == 0, "map_shared into a non-empty slot"
        assert len(pages) <= self.max_pages_per_seq
        for i, p in enumerate(pages):
            assert self.refcount[p] > 0 or self.indexed[p] or \
                self._pins[p] > 0, f"sharing an unallocated page {p}"
            self.page_table[slot, i] = p
            self.refcount[p] += 1
            self.shared_count += 1
        self._owned[slot] = len(pages)

    def release(self, slot: int) -> int:
        """Unmap every page owned by ``slot`` (decref); returns how many
        actually went back to the free list (shared or index-cached
        pages survive their last slot reference)."""
        n = self.owned(slot)
        freed = 0
        before = len(self._free)
        for i in range(n):
            p = int(self.page_table[slot, i])
            self.page_table[slot, i] = -1
            self.refcount[p] -= 1
            assert self.refcount[p] >= 0
            self._maybe_free(p)
        freed = len(self._free) - before
        self._owned[slot] = 0
        return freed

    # ------------------------------------------------------------------
    # prefix-index holds + CoW pins
    # ------------------------------------------------------------------
    def index_page(self, p: int):
        """Mark ``p`` as retained by the prefix index (it survives its
        owning slot's release)."""
        assert self.refcount[p] > 0 or self.indexed[p] or \
            self._pins[p] > 0, f"indexing an unallocated page {p}"
        self.indexed[p] = True

    def unindex_page(self, p: int) -> bool:
        """Drop the index's hold on ``p``; returns True if the page went
        back to the free list (no slot was still mapping it)."""
        assert self.indexed[p]
        self.indexed[p] = False
        before = len(self._free)
        self._maybe_free(p)
        return len(self._free) > before

    def pin(self, p: int):
        """Guard ``p`` against eviction/free until :meth:`unpin` — used
        across the CoW admission window (match -> device copy)."""
        assert self.refcount[p] > 0 or self.indexed[p] or \
            self._pins[p] > 0, f"pinning an unallocated page {p}"
        self._pins[p] += 1

    def unpin(self, p: int):
        assert self._pins[p] > 0
        self._pins[p] -= 1
        self._maybe_free(p)

    # ------------------------------------------------------------------
    def rows(self, slots: np.ndarray) -> np.ndarray:
        """Page-table rows for a batch of slots (copy; safe to mutate)."""
        return self.page_table[np.asarray(slots, np.int64)].copy()

    # ------------------------------------------------------------------
    # invariants (used by the preemption/chunking regression tests and
    # the refcount/CoW hypothesis fuzz)
    # ------------------------------------------------------------------
    def mapped_pages(self) -> np.ndarray:
        """Sorted physical ids of every table entry (with sharing, a
        page mapped by k slots appears k times)."""
        return np.sort(self.page_table[self.page_table >= 0])

    def check_consistent(self):
        """Assert the allocator invariants:

          * refcounts match table membership exactly (a page's refcount
            is the number of table entries mapping it — chunk-resume
            can never double-write a page because a slot maps each of
            its logical pages once, and writes only land above the
            shared prefix),
          * no page is simultaneously free and referenced (by a table
            entry, the prefix index, or a pin),
          * free + referenced cover the pool exactly (no leaks),
          * the free list holds no duplicates,
          * per-slot tables are contiguous and agree with ``_owned``.
        """
        entries = self.page_table[self.page_table >= 0]
        counts = np.bincount(entries, minlength=self.num_pages) \
            if len(entries) else np.zeros(self.num_pages, np.int64)
        assert (counts == self.refcount).all(), \
            "refcounts disagree with page-table membership"
        assert len(self._free) == len(set(self._free)), \
            "free list holds a duplicate page"
        free = np.zeros(self.num_pages, bool)
        free[np.asarray(self._free, np.int64)] = True
        referenced = (self.refcount > 0) | self.indexed | (self._pins > 0)
        assert not (free & referenced).any(), \
            "a page is both free and referenced"
        assert (free | referenced).all(), \
            "pages leaked: neither free nor referenced"
        for s in range(self.max_seqs):
            n = int(self._owned[s])
            assert (self.page_table[s, :n] >= 0).all(), \
                "hole inside an owned table prefix"
            assert (self.page_table[s, n:] == -1).all(), \
                "table entry beyond the owned count"
