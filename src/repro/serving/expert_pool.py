"""Paged expert-weight pool with activation-aware prefetch.

The paper's core claim is that memory-bound MoE decode time is HBM
traffic for *activated* expert weights — which is exactly a working
set.  This module treats it as one, the way :mod:`repro.serving.kv`
treats KV: expert weights live in fixed-size per-(moe_layer, physical
slot) **pages**, a bounded set of HBM **frames** holds the resident
pages, cold pages stay in the host backing store, and the router's
step-``t`` output drives an activation-aware prefetch of step
``t+1``'s pages (HarMoEny-style asynchronous expert fetching).

Allocator discipline mirrors ``PagedKVManager``: LIFO free list,
refcounted pins while a step computes, LRU eviction among unpinned
frames, and :meth:`check_consistent` proving free/resident frames are
disjoint and exhaustive with ``page_frame``/``frame_page`` mutual
inverses.

Fetch accounting is split three ways, because the three kinds stall
differently:

* **miss** — a page accessed this step that no prior plan fetched;
  the step waits for it (demand fetch, serial).
* **prefetch** — fetched ahead under the previous step's plan, up to
  ``prefetch_depth`` pages; overlapped with compute (the
  double-buffered DMA path in ``kernels/moe_ffn.py``).
* **gate** — planned pages the depth budget deferred, flushed by the
  scheduler's decode residency gate *before* the next decode step
  runs (attributed as a decode stall).

Bit-identity invariant: the pool is bookkeeping + virtual-time cost —
a fetch always completes before the weights are used, so residency
never changes the math.  ``benchmarks/bench_expert_paging.py`` asserts
served tokens under a capacity-limited pool are bit-identical to the
all-resident run.
"""
from __future__ import annotations

import numpy as np

__all__ = ["ExpertPagePool", "expert_page_bytes", "moe_layer_count",
           "build_expert_pool"]


def expert_page_bytes(cfg, bytes_per_param: int = 2) -> int:
    """Bytes of one physical expert slot's FFN weights (up + down),
    bf16 by default — the unit the pool pages in and out."""
    d, fe = cfg.d_model, cfg.expert_hidden
    n_up = 2 if cfg.gated_mlp else 1
    return int((d * n_up * fe + fe * d) * bytes_per_param)


def moe_layer_count(cfg) -> int:
    """Number of MoE FFN layers in the full stack."""
    kinds = cfg.layer_kinds()
    n_moe = sum(1 for _, f in kinds if f == "moe")
    return (cfg.num_layers // len(kinds)) * n_moe


class ExpertPagePool:
    """HBM frame allocator for per-(layer, slot) expert-weight pages.

    Pages are identified by a flat ``pid = layer * n_slots + slot``.
    A page is *resident* iff ``page_frame[pid] >= 0``; a frame is
    *free* iff it is on the free list (and then maps no page).
    ``acquire`` pins the accessed pages for the duration of one
    layer's compute, ``release`` unpins them — the page stays resident
    (cached) until LRU eviction reclaims its frame for another fetch.
    """

    def __init__(self, *, n_layers: int, n_slots: int, page_bytes: int,
                 num_frames: int, h2d_bw: float = 1.6e10,
                 prefetch_depth: int = 8):
        assert n_layers >= 1 and n_slots >= 1 and page_bytes >= 1
        self.n_layers = n_layers
        self.n_slots = n_slots
        self.page_bytes = int(page_bytes)
        self.total_pages = n_layers * n_slots
        self.num_frames = int(min(num_frames, self.total_pages))
        # capacity floor: one layer's worst-case activated set must fit
        # (acquire pins at most n_slots pages at once, so eviction can
        # always find an unpinned victim)
        assert self.num_frames >= n_slots, (
            f"pool of {num_frames} frames cannot hold one layer's "
            f"{n_slots} slots")
        self.h2d_bw = float(h2d_bw)
        self.prefetch_depth = int(prefetch_depth)

        self._free = list(range(self.num_frames - 1, -1, -1))
        self.page_frame = np.full(self.total_pages, -1, np.int64)
        self.frame_page = np.full(self.num_frames, -1, np.int64)
        self.refcount = np.zeros(self.num_frames, np.int64)
        self._stamp = np.zeros(self.num_frames, np.int64)   # LRU clock
        self._tick = 0
        self._planned: set[int] = set()     # last plan_prefetch pids
        self._pending: list[int] = []       # planned, deferred by depth

        # counters (monotone; SLO/bench read deltas or totals)
        self.accesses = 0
        self.hits = 0
        self.misses = 0
        self.planned_hits = 0               # accessed page was in plan
        self.prefetch_issued = 0
        self.evictions = 0
        self.invalidations = 0
        self.miss_bytes = 0
        self.prefetch_bytes = 0
        self.gate_bytes = 0
        # host->HBM bytes split by engine step kind and fetch reason
        self.bytes_by_kind: dict[str, dict[str, int]] = {}

    # ------------------------------------------------------------------
    def page_id(self, layer: int, slot: int) -> int:
        assert 0 <= layer < self.n_layers and 0 <= slot < self.n_slots
        return layer * self.n_slots + slot

    def resident(self, pid: int) -> bool:
        return self.page_frame[pid] >= 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def resident_pages(self) -> int:
        return self.num_frames - len(self._free)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0

    @property
    def prefetch_coverage(self) -> float:
        """Fraction of accesses the previous step's plan named —
        1.0 when the router runs exactly one step ahead (oracle)."""
        return self.planned_hits / self.accesses if self.accesses else 0.0

    def stall_seconds(self, nbytes: int) -> float:
        return nbytes / self.h2d_bw

    # ------------------------------------------------------------------
    def _touch(self, f: int):
        self._tick += 1
        self._stamp[f] = self._tick

    def _evict_one(self) -> int:
        """Reclaim the least-recently-used unpinned resident frame."""
        mapped = self.frame_page >= 0
        victims = np.nonzero(mapped & (self.refcount == 0))[0]
        if len(victims) == 0:
            raise RuntimeError(
                "expert pool exhausted: every resident frame is pinned")
        f = int(victims[np.argmin(self._stamp[victims])])
        self.page_frame[self.frame_page[f]] = -1
        self.frame_page[f] = -1
        self.evictions += 1
        return f

    def _account(self, kind: str, reason: str, nbytes: int):
        per = self.bytes_by_kind.setdefault(
            kind, {"miss": 0, "prefetch": 0, "gate": 0})
        per[reason] += nbytes

    def _fetch(self, pid: int, kind: str, reason: str) -> int:
        """Bring ``pid`` into a frame from the host backing store."""
        assert not self.resident(pid)
        f = self._free.pop() if self._free else self._evict_one()
        self.frame_page[f] = pid
        self.page_frame[pid] = f
        self._touch(f)
        setattr(self, f"{reason}_bytes",
                getattr(self, f"{reason}_bytes") + self.page_bytes)
        self._account(kind, reason, self.page_bytes)
        return f

    # ------------------------------------------------------------------
    def acquire(self, pids, kind: str = "decode") -> dict:
        """Pin the pages one layer's compute touches; demand-fetch any
        that are not resident.  Returns this call's hit/miss split."""
        n_hit = n_miss = n_planned = 0
        for pid in pids:
            self.accesses += 1
            if pid in self._planned:
                self.planned_hits += 1
                n_planned += 1
            if self.resident(pid):
                self.hits += 1
                n_hit += 1
                self._touch(int(self.page_frame[pid]))
            else:
                self.misses += 1
                n_miss += 1
                self._fetch(pid, kind, "miss")
            self.refcount[self.page_frame[pid]] += 1
        return {"hits": n_hit, "misses": n_miss,
                "planned_hits": n_planned,
                "miss_bytes": n_miss * self.page_bytes}

    def release(self, pids):
        for pid in pids:
            f = self.page_frame[pid]
            assert f >= 0 and self.refcount[f] > 0, \
                f"release of unpinned page {pid}"
            self.refcount[f] -= 1

    # ------------------------------------------------------------------
    def plan_prefetch(self, pids, kind: str = "decode") -> int:
        """Install step ``t``'s activated pages as the plan for step
        ``t+1``; start up to ``prefetch_depth`` overlapped fetches and
        queue the rest for the decode residency gate.  Returns the
        bytes issued (overlapped — they cost max(compute, DMA), not
        compute + DMA).  ``prefetch_depth == 0`` disables planning
        entirely (every cold access becomes a demand miss)."""
        if self.prefetch_depth <= 0:
            return 0
        self._planned = set(pids)
        self._pending = []
        issued = 0
        budget = self.prefetch_depth
        for pid in pids:
            if self.resident(pid):
                self._touch(int(self.page_frame[pid]))
                continue
            if budget > 0:
                self._fetch(pid, kind, "prefetch")
                self.prefetch_issued += 1
                issued += self.page_bytes
                budget -= 1
            else:
                self._pending.append(pid)
        return issued

    def flush_pending(self, kind: str = "decode") -> int:
        """The decode residency gate: synchronously fetch every planned
        page the prefetch depth deferred.  Returns the bytes fetched
        (the caller attributes ``stall_seconds(bytes)`` of stall)."""
        nbytes = 0
        for pid in self._pending:
            if not self.resident(pid):
                self._fetch(pid, kind, "gate")
                nbytes += self.page_bytes
        self._pending = []
        return nbytes

    # ------------------------------------------------------------------
    def invalidate_slots(self, slots) -> int:
        """Drop residency for ``slots`` across every layer — an EPLB
        reshuffle rewrote those physical slots' weights, so the cached
        pages are stale.  Must run between steps (nothing pinned)."""
        dropped = 0
        for s in slots:
            for layer in range(self.n_layers):
                pid = self.page_id(layer, int(s))
                f = int(self.page_frame[pid])
                if f < 0:
                    continue
                assert self.refcount[f] == 0, \
                    "invalidate while page pinned"
                self.page_frame[pid] = -1
                self.frame_page[f] = -1
                self._free.append(f)
                dropped += 1
        if dropped:
            self.invalidations += dropped
            self._planned = set()
            self._pending = []
        return dropped

    # ------------------------------------------------------------------
    def counters(self) -> dict:
        return {
            "accesses": self.accesses, "hits": self.hits,
            "misses": self.misses, "planned_hits": self.planned_hits,
            "hit_rate": self.hit_rate,
            "prefetch_coverage": self.prefetch_coverage,
            "prefetch_issued": self.prefetch_issued,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "miss_bytes": self.miss_bytes,
            "prefetch_bytes": self.prefetch_bytes,
            "gate_bytes": self.gate_bytes,
            "h2d_bytes": self.miss_bytes + self.prefetch_bytes
            + self.gate_bytes,
            "num_frames": self.num_frames,
            "resident_pages": self.resident_pages,
            "bytes_by_kind": {k: dict(v)
                              for k, v in self.bytes_by_kind.items()},
        }

    def check_consistent(self):
        """Allocator invariants, mirroring ``PagedKVManager``."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list duplicates"
        mapped = {int(f) for f in np.nonzero(self.frame_page >= 0)[0]}
        assert not (free & mapped), "frame both free and resident"
        assert free | mapped == set(range(self.num_frames)), \
            "frame neither free nor resident"
        for f in mapped:
            pid = int(self.frame_page[f])
            assert self.page_frame[pid] == f, \
                f"frame {f} -> page {pid} not mutually mapped"
        res = np.nonzero(self.page_frame >= 0)[0]
        assert len(res) == len(mapped), "page/frame residency mismatch"
        for pid in res:
            f = int(self.page_frame[pid])
            assert self.frame_page[f] == pid, \
                f"page {pid} -> frame {f} not mutually mapped"
        assert (self.refcount >= 0).all(), "negative refcount"
        pinned = np.nonzero(self.refcount > 0)[0]
        assert all(int(f) in mapped for f in pinned), \
            "pinned frame holds no page"
        assert set(self._pending) <= self._planned, \
            "pending page outside the prefetch plan"


def build_expert_pool(cfg, ecfg, n_slots: int):
    """Size a pool from the engine config: ``hbm_budget_bytes == 0``
    means every page gets a frame (all-resident; only compulsory
    misses), otherwise the budget buys ``budget // page_bytes`` frames
    floored at one layer's slot set."""
    pb = expert_page_bytes(cfg)
    n_layers = moe_layer_count(cfg)
    total = n_layers * n_slots
    if ecfg.hbm_budget_bytes <= 0:
        frames = total
    else:
        frames = max(int(ecfg.hbm_budget_bytes) // pb, n_slots)
    return ExpertPagePool(
        n_layers=n_layers, n_slots=n_slots, page_bytes=pb,
        num_frames=frames, h2d_bw=ecfg.pool_h2d_bw,
        prefetch_depth=ecfg.prefetch_depth)
