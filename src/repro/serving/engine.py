"""Continuous-batching serving engine with prefill/decode co-deployment.

The paper's real-system setting (§VI-A): prefill and decode co-deployed,
EPLB expert placement/replication as the fixed substrate, token routing
selectable per phase — METRO for the memory-bound decode phase, EPLB's
round-robin for prefill (exactly the paper's deployment).

Engine loop per iteration (vLLM-style):
  1. admit waiting requests into free slots (up to max_batch),
  2. if any admitted this round: run one (chunked) prefill per request,
  3. run one decode step for the whole active batch,
  4. retire finished requests; every ``rebalance_every`` decode steps,
     recompute EPLB placement from the observed expert-load EWMA and
     reshuffle the physical expert weights (weight "shuffling" is a
     gather over the logical master copy, as vLLM's EPLB does).

Batch-size bucketing mirrors the paper's CUDA-graph integration (§V):
decode steps are jitted per power-of-two batch bucket and smaller
batches pad to the bucket, so step functions compile once per bucket.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import build_placement
from repro.models import lm as LM
from repro.serving.slo import SLOTracker
from repro.sharding.policy import Dist


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [n] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0                # next position to fill
    done: bool = False


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8          # decode slots
    max_len: int = 256          # KV capacity per slot
    replication_ratio: float = 1.25
    decode_algo: str = "metro"  # the paper's technique
    prefill_algo: str = "eplb"
    rebalance_every: int = 64   # decode steps between EPLB rebalances
    load_ewma: float = 0.9
    prefill_chunk: int = 64     # chunked prefill (sarathi-style)
    greedy: bool = True
    seed: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, dist: Dist, params,
                 ecfg: EngineConfig, routing_table_width: int = 0):
        self.cfg = cfg
        self.dist = dist
        self.ecfg = ecfg
        self.params = params
        self.slo = SLOTracker()
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.completed: dict[int, Request] = {}
        self.free_slots = list(range(ecfg.max_batch))
        self.decode_steps = 0
        self.expert_loads = np.ones(max(cfg.num_experts, 1))
        self._table_width = routing_table_width

        if cfg.is_moe:
            self.placement = build_placement(
                cfg.num_experts, dist.ep_size, dist.slots_per_device,
                loads=self.expert_loads)
            if not self._table_width:
                self._table_width = min(
                    dist.num_slots - cfg.num_experts + 1, dist.ep_size * 2)
                self._table_width = max(self._table_width,
                                        self.placement.max_replicas)
            self.routing = LM.build_lm_routing(cfg, self.placement,
                                               self._table_width)
            # logical master weights (for rebalance reshuffling)
            self._logical = self._extract_logical(params)
        else:
            self.placement, self.routing = None, {}

        self.cache = LM.init_cache(cfg, dist, ecfg.max_batch, ecfg.max_len)
        self._decode_fns = {}
        self._prefill_fns = {}

    # ------------------------------------------------------------------
    # weight reshuffling (EPLB rebalance)
    # ------------------------------------------------------------------
    def _extract_logical(self, params):
        """Logical expert master: replica 0 of each expert."""
        first_slot = np.array([
            self.placement.expert_slots[e, 0]
            for e in range(self.cfg.num_experts)])
        out = {}

        def grab(tree, path=()):
            for k, v in tree.items():
                if isinstance(v, dict):
                    grab(v, path + (k,))
                elif k in ("w_up", "w_down") and v.ndim >= 4:
                    out[path + (k,)] = np.asarray(v)[:, first_slot]
        grab(params["blocks"])
        return out

    def rebalance(self):
        """Recompute EPLB placement from observed loads + reshuffle."""
        if not self.cfg.is_moe:
            return
        self.placement = build_placement(
            self.cfg.num_experts, self.dist.ep_size,
            self.dist.slots_per_device, loads=self.expert_loads)
        self.routing = LM.build_lm_routing(self.cfg, self.placement,
                                           self._table_width)
        idx = self.placement.replica_expert

        def put(tree, path=()):
            for k, v in list(tree.items()):
                if isinstance(v, dict):
                    put(v, path + (k,))
                elif k in ("w_up", "w_down") and v.ndim >= 4:
                    tree[k] = jnp.asarray(self._logical[path + (k,)][:, idx])
        put(self.params["blocks"])

    # ------------------------------------------------------------------
    # step functions (bucketed)
    # ------------------------------------------------------------------
    def _decode_fn(self, bucket: int):
        if bucket not in self._decode_fns:
            cfg, dist = self.cfg, self.dist

            @jax.jit
            def step(params, tokens, pos, cache, routing):
                logits, new_cache, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, pos=pos, cache=cache,
                    routing=routing, mode="decode",
                    algo=self.ecfg.decode_algo)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, new_cache, stats
            self._decode_fns[bucket] = step
        return self._decode_fns[bucket]

    def _prefill_fn(self, length: int):
        if length not in self._prefill_fns:
            cfg, dist = self.cfg, self.dist

            @jax.jit
            def step(params, tokens, cache, routing):
                logits, new_cache, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, cache=cache,
                    routing=routing, mode="prefill",
                    algo=self.ecfg.prefill_algo, chunk=64)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, new_cache, stats
            self._prefill_fns[length] = step
        return self._prefill_fns[length]

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = len(self.slo.timings)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        self.slo.arrive(rid, len(prompt))
        return rid

    def _admit(self):
        admitted = []
        while self.queue and self.free_slots:
            r = self.queue.popleft()
            r.slot = self.free_slots.pop()
            self.active[r.rid] = r
            admitted.append(r)
        return admitted

    def _bucket(self) -> int:
        return self.ecfg.max_batch  # fixed-slot engine: pad to max_batch

    def _prefill(self, req: Request):
        """Single-request prefill into its cache slot (padded length)."""
        n = len(req.prompt)
        pl = 1 << (n - 1).bit_length()  # pad to pow2 for compile reuse
        pl = max(pl, 8)
        toks = np.zeros((1, pl), np.int32)
        toks[0, :n] = req.prompt
        cache1 = jax.tree.map(lambda a: a[:, req.slot:req.slot + 1]
                              if a.ndim >= 2 else a, self.cache)
        t0 = time.perf_counter()
        nxt, new_c1, stats = self._prefill_fn(pl)(
            self.params, jnp.asarray(toks), cache1, self.routing)
        nxt.block_until_ready()
        self.slo.step("prefill", time.perf_counter() - t0)
        # note: prefill computed over padded length; positions >= n hold
        # garbage but are masked at decode by pos-based validity
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, req.slot:req.slot + 1].set(one)
            if full.ndim >= 2 else one, self.cache, new_c1)
        req.pos = n
        # first generated token comes from the last *real* position: use
        # greedy over the prefill logits of position n-1 — the padded
        # tail means we take the model's next step in decode instead.
        self._update_loads(stats)

    def _update_loads(self, stats):
        if not self.cfg.is_moe:
            return
        h = np.asarray(stats["expert_hist"])
        if h.shape[0] == self.cfg.num_experts:
            a = self.ecfg.load_ewma
            self.expert_loads = a * self.expert_loads + (1 - a) * (h + 1e-3)

    def _decode_all(self):
        if not self.active:
            return
        b = self.ecfg.max_batch
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for r in self.active.values():
            last = (r.generated[-1] if r.generated
                    else int(r.prompt[-1]))
            tokens[r.slot, 0] = last
            pos[r.slot] = r.pos
        t0 = time.perf_counter()
        nxt, self.cache, stats = self._decode_fn(b)(
            self.params, jnp.asarray(tokens), jnp.asarray(pos),
            self.cache, self.routing)
        nxt = np.asarray(nxt)
        self.slo.step("decode", time.perf_counter() - t0)
        self.decode_steps += 1
        self._update_loads(stats)
        for rid in list(self.active):
            r = self.active[rid]
            tok = int(nxt[r.slot])
            if not r.generated:
                self.slo.first_token(rid)
            else:
                self.slo.token(rid)
            r.generated.append(tok)
            r.pos += 1
            if (len(r.generated) >= r.max_new_tokens
                    or r.pos >= self.ecfg.max_len - 1):
                r.done = True
                self.slo.finish(rid)
                self.free_slots.append(r.slot)
                self.completed[rid] = r
                del self.active[rid]
        if (self.cfg.is_moe and self.ecfg.rebalance_every
                and self.decode_steps % self.ecfg.rebalance_every == 0):
            self.rebalance()

    # ------------------------------------------------------------------
    def run(self, max_iters: int = 10_000):
        """Run until queue + active drain (or max_iters)."""
        it = 0
        while (self.queue or self.active) and it < max_iters:
            for req in self._admit():
                self._prefill(req)
            self._decode_all()
            it += 1
        return self.slo.summary()

    def finished_requests(self):
        return {rid: t for rid, t in self.slo.timings.items()
                if t.finished > 0}
