"""Continuous-batching serving engine with prefill/decode co-deployment.

The paper's real-system setting (§VI-A): prefill and decode co-deployed,
EPLB expert placement/replication as the fixed substrate, token routing
selectable per phase — METRO for the memory-bound decode phase, EPLB's
round-robin for prefill (exactly the paper's deployment).

Engine loop per iteration (vLLM-style):
  1. admit waiting requests into free slots (and, for the paged KV
     layout, reserve their prompt pages from the shared pool),
  2. run ONE batched chunked prefill over the admitted wave — prompts
     are packed into a single padded ``[B, L]`` call so METRO/EPLB
     routing sees realistic mixed-length batches,
  3. run one decode step for the active set, gathered into the smallest
     power-of-two batch bucket (``bucket_mode="pow2"``) instead of
     always padding to ``max_batch``,
  4. retire finished requests; every ``rebalance_every`` decode steps,
     recompute EPLB placement from the observed expert-load EWMA and
     reshuffle the physical expert weights.

Batch-size bucketing mirrors the paper's CUDA-graph integration (§V):
step functions are jitted once per (bucket, padded-length) signature and
reused for every batch that rounds up to it; the ``SLOTracker`` counts
each fresh compile, so compile traffic is O(log max_batch · log max_len)
on any trace.

KV storage is paged by default (``kv_layout="paged"``): attention layers
share a flat pool of fixed-size pages (``serving/kv.py``), each sequence
owns only the pages its tokens occupy, and page tables are step *inputs*
— growing a sequence or admitting past the dense-residency limit never
recompiles.  When the pool runs dry the engine preempts the youngest
sequence (free its pages, requeue, recompute on readmission), so
``max_batch`` can exceed the worst-case-resident limit
``num_pages * page_size / max_len``.  ``kv_layout="dense"`` keeps the
seed's ``[max_batch, max_len]`` buffers for A/B comparison, and
``bucket_mode="fixed"`` + ``batch_prefill=False`` reproduces the seed
scheduler exactly.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import build_placement
from repro.models import lm as LM
from repro.serving.kv import PagedKVManager, pages_for
from repro.serving.slo import SLOTracker
from repro.sharding.policy import Dist


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [n] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0                # next position to fill
    done: bool = False
    preempted: int = 0          # times evicted under page pressure

    def context_tokens(self) -> np.ndarray:
        """Tokens to (re)prefill: the prompt plus anything generated
        before a preemption (recompute-on-readmission)."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8          # decode slots
    max_len: int = 256          # KV capacity per sequence
    replication_ratio: float = 1.25
    decode_algo: str = "metro"  # the paper's technique
    prefill_algo: str = "eplb"
    rebalance_every: int = 64   # decode steps between EPLB rebalances
    load_ewma: float = 0.9
    prefill_chunk: int = 64     # chunked prefill (sarathi-style)
    greedy: bool = True
    seed: int = 0
    # --- scheduling ---
    bucket_mode: str = "pow2"   # "pow2" | "fixed" (seed: pad to max_batch)
    batch_prefill: bool = True  # pack the admitted wave into one call
    max_wave: int = 0           # prefill wave cap; 0 -> max_batch
    bucket_compile_grace: int = 4   # steps a cold bucket rounds up to a
                                    # compiled one before earning its own
                                    # compile (0 = always compile exact)
    # --- KV layout ---
    kv_layout: str = "paged"    # "paged" | "dense" (seed layout)
    page_size: int = 16         # tokens per KV page
    num_pages: int = 0          # pool size; 0 -> full residency
                                #   (max_batch * ceil(max_len/page_size))


def _pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())


class ServingEngine:
    def __init__(self, cfg: ModelConfig, dist: Dist, params,
                 ecfg: EngineConfig, routing_table_width: int = 0):
        assert ecfg.bucket_mode in ("pow2", "fixed"), ecfg.bucket_mode
        assert ecfg.kv_layout in ("paged", "dense"), ecfg.kv_layout
        self.cfg = cfg
        self.dist = dist
        self.ecfg = ecfg
        self.params = params
        self.slo = SLOTracker()
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.completed: dict[int, Request] = {}
        self.free_slots = list(range(ecfg.max_batch))
        self.decode_steps = 0
        self.expert_loads = np.ones(max(cfg.num_experts, 1))
        self._table_width = routing_table_width
        self._next_rid = 0

        if cfg.is_moe:
            self.placement = build_placement(
                cfg.num_experts, dist.ep_size, dist.slots_per_device,
                loads=self.expert_loads)
            if not self._table_width:
                self._table_width = min(
                    dist.num_slots - cfg.num_experts + 1, dist.ep_size * 2)
                self._table_width = max(self._table_width,
                                        self.placement.max_replicas)
            self.routing = LM.build_lm_routing(cfg, self.placement,
                                               self._table_width)
            # logical master weights (for rebalance reshuffling)
            self._logical = self._extract_logical(params)
        else:
            self.placement, self.routing = None, {}

        if ecfg.kv_layout == "paged":
            pmax = pages_for(ecfg.max_len, ecfg.page_size)
            num_pages = ecfg.num_pages or ecfg.max_batch * pmax
            self.kvman: Optional[PagedKVManager] = PagedKVManager(
                num_pages=num_pages, page_size=ecfg.page_size,
                max_pages_per_seq=pmax, max_seqs=ecfg.max_batch)
            self.cache = LM.init_paged_cache(
                cfg, dist, num_pages, ecfg.page_size, ecfg.max_batch)
        else:
            self.kvman = None
            self.cache = LM.init_cache(cfg, dist, ecfg.max_batch,
                                       ecfg.max_len)
        self._fns: dict[str, dict] = {"decode": {}, "prefill": {}}
        self._bucket_demand: dict[int, int] = {}

    # ------------------------------------------------------------------
    # weight reshuffling (EPLB rebalance)
    # ------------------------------------------------------------------
    def _extract_logical(self, params):
        """Logical expert master: replica 0 of each expert."""
        first_slot = np.array([
            self.placement.expert_slots[e, 0]
            for e in range(self.cfg.num_experts)])
        out = {}

        def grab(tree, path=()):
            for k, v in tree.items():
                if isinstance(v, dict):
                    grab(v, path + (k,))
                elif k in ("w_up", "w_down") and v.ndim >= 4:
                    out[path + (k,)] = np.asarray(v)[:, first_slot]
        grab(params["blocks"])
        return out

    def rebalance(self):
        """Recompute EPLB placement from observed loads + reshuffle."""
        if not self.cfg.is_moe:
            return
        self.placement = build_placement(
            self.cfg.num_experts, self.dist.ep_size,
            self.dist.slots_per_device, loads=self.expert_loads)
        self.routing = LM.build_lm_routing(self.cfg, self.placement,
                                           self._table_width)
        idx = self.placement.replica_expert

        def put(tree, path=()):
            for k, v in list(tree.items()):
                if isinstance(v, dict):
                    put(v, path + (k,))
                elif k in ("w_up", "w_down") and v.ndim >= 4:
                    tree[k] = jnp.asarray(self._logical[path + (k,)][:, idx])
        put(self.params["blocks"])

    # ------------------------------------------------------------------
    # step functions (compiled once per shape signature)
    # ------------------------------------------------------------------
    def _get_fn(self, kind: str, key, builder):
        fns = self._fns[kind]
        if key not in fns:
            fns[key] = builder()
            self.slo.compiled(kind, key)
        return fns[key]

    def _decode_fn(self, bucket: int):
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            paged = ecfg.kv_layout == "paged"

            @jax.jit
            def step(params, tokens, pos, slot_idx, page_table, cache,
                     routing):
                logits, new_cache, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, pos=pos, cache=cache,
                    routing=routing, mode="decode", algo=ecfg.decode_algo,
                    slot_idx=slot_idx,
                    page_table=page_table if paged else None,
                    row_valid=slot_idx < ecfg.max_batch)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, new_cache, stats
            return step
        return self._get_fn("decode", bucket, build)

    def _prefill_fn(self, batch: int, length: int):
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            paged = ecfg.kv_layout == "paged"

            @jax.jit
            def step(params, tokens, lengths, slot_idx, page_table, cache,
                     routing):
                wave = LM.init_wave_cache(cfg, dist, batch, length)
                _, filled, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, cache=wave,
                    routing=routing, mode="prefill",
                    algo=ecfg.prefill_algo, chunk=ecfg.prefill_chunk,
                    row_valid=jnp.arange(length)[None, :]
                    < lengths[:, None])
                new_cache = LM.merge_wave_cache(
                    cfg, cache, filled, slot_idx, lengths,
                    page_table=page_table if paged else None,
                    page_size=ecfg.page_size)
                return new_cache, stats
            return step
        return self._get_fn("prefill", (batch, length), build)

    # ------------------------------------------------------------------
    # admission / paging
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        prompt = np.asarray(prompt, np.int32)
        assert len(prompt) < self.ecfg.max_len, (
            f"prompt of {len(prompt)} tokens exceeds max_len-1="
            f"{self.ecfg.max_len - 1}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, max_new_tokens))
        self.slo.arrive(rid, len(prompt))
        return rid

    def _admit(self) -> list[Request]:
        admitted = []
        while self.queue and self.free_slots:
            r = self.queue[0]
            n_ctx = min(len(r.context_tokens()), self.ecfg.max_len - 1)
            if self.kvman is not None:
                need = pages_for(n_ctx, self.ecfg.page_size)
                if need > self.kvman.num_free:
                    break           # FCFS head-of-line: wait for pages
            self.queue.popleft()
            r.slot = self.free_slots.pop()
            if self.kvman is not None:
                ok = self.kvman.ensure(r.slot, n_ctx)
                assert ok, "admission page reservation failed"
            self.active[r.rid] = r
            admitted.append(r)
        return admitted

    def _preempt_one(self, protect_rid: int) -> bool:
        """Evict the youngest active request (≠ protect_rid): free its
        pages + slot and requeue it for recompute-on-readmission."""
        victims = [r for r in self.active.values() if r.rid != protect_rid]
        if not victims:
            return False
        v = max(victims, key=lambda r: r.rid)
        self.kvman.release(v.slot)
        self.free_slots.append(v.slot)
        del self.active[v.rid]
        v.slot, v.pos, v.preempted = -1, 0, v.preempted + 1
        self.queue.appendleft(v)
        self.slo.preemptions += 1
        return True

    # ------------------------------------------------------------------
    # prefill (batched wave)
    # ------------------------------------------------------------------
    def _prefill_wave(self, wave: list[Request]):
        group_cap = (self.ecfg.max_wave or self.ecfg.max_batch) \
            if self.ecfg.batch_prefill else 1
        for i in range(0, len(wave), group_cap):
            self._prefill_group(wave[i:i + group_cap])

    def _prefill_group(self, group: list[Request]):
        ecfg = self.ecfg
        ctxs = [r.context_tokens() for r in group]
        lens = [min(len(c), ecfg.max_len - 1) for c in ctxs]
        b = _pow2(len(group))
        l_pad = min(max(_pow2(max(lens)), 8), ecfg.max_len)
        pmax = pages_for(ecfg.max_len, ecfg.page_size)
        toks = np.zeros((b, l_pad), np.int32)
        lengths = np.zeros((b,), np.int32)
        slot_idx = np.full((b,), ecfg.max_batch, np.int32)  # OOB = pad row
        pt = np.full((b, pmax), -1, np.int32)
        for i, r in enumerate(group):
            toks[i, :lens[i]] = ctxs[i][:lens[i]]
            lengths[i] = lens[i]
            slot_idx[i] = r.slot
        if self.kvman is not None:
            pt[:len(group)] = self.kvman.rows([r.slot for r in group])
        fn = self._prefill_fn(b, l_pad)
        t0 = time.perf_counter()
        self.cache, stats = fn(
            self.params, jnp.asarray(toks), jnp.asarray(lengths),
            jnp.asarray(slot_idx), jnp.asarray(pt), self.cache,
            self.routing)
        jax.block_until_ready(stats)
        self.slo.step("prefill", time.perf_counter() - t0)
        for r, n in zip(group, lens):
            r.pos = n
        self._update_loads(stats)

    def _update_loads(self, stats):
        if not self.cfg.is_moe:
            return
        h = np.asarray(stats["expert_hist"])
        if h.shape[0] == self.cfg.num_experts:
            a = self.ecfg.load_ewma
            self.expert_loads = a * self.expert_loads + (1 - a) * (h + 1e-3)

    # ------------------------------------------------------------------
    # decode (bucketed)
    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        """Decode batch bucket for n active sequences.

        Power-of-two rounding, with a compile-avoidance grace: a bucket
        nobody has compiled yet first borrows the smallest compiled
        bucket above it (correct — extra rows are padding) and only
        earns its own compile after ``bucket_compile_grace`` uses.  This
        keeps end-of-trace drain-down from compiling each small bucket
        for a handful of steps, while sustained low occupancy (a long
        low-rate phase, a straggler tail) still gets its fast bucket.
        """
        if self.ecfg.bucket_mode == "fixed":
            return self.ecfg.max_batch
        b = min(_pow2(max(n, 1)), self.ecfg.max_batch)
        fns = self._fns["decode"]
        if b in fns:
            return b
        bigger = [k for k in fns if k > b]
        if not bigger:
            return b
        self._bucket_demand[b] = self._bucket_demand.get(b, 0) + 1
        if self._bucket_demand[b] > self.ecfg.bucket_compile_grace:
            return b
        return min(bigger)

    def _grow_pages(self):
        """Make sure every active sequence has a page for this step's
        token, preempting the youngest sequences under pool pressure."""
        if self.kvman is None:
            return
        for r in sorted(self.active.values(), key=lambda r: r.rid):
            if r.rid not in self.active:    # evicted by a prior grow
                continue
            want = min(r.pos + 1, self.ecfg.max_len)
            while not self.kvman.ensure(r.slot, want):
                if not self._preempt_one(protect_rid=r.rid):
                    raise RuntimeError(
                        "KV page pool exhausted by a single sequence; "
                        "num_pages must be >= ceil(max_len/page_size)")

    def _decode_all(self):
        if not self.active:
            return
        self._grow_pages()
        actives = sorted(self.active.values(), key=lambda r: r.slot)
        n = len(actives)
        b = self._bucket(n)
        ecfg = self.ecfg
        pmax = pages_for(ecfg.max_len, ecfg.page_size)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        slot_idx = np.full((b,), ecfg.max_batch, np.int32)
        pt = np.full((b, pmax), -1, np.int32)
        for i, r in enumerate(actives):
            tokens[i, 0] = (r.generated[-1] if r.generated
                            else int(r.context_tokens()[-1]))
            pos[i] = r.pos
            slot_idx[i] = r.slot
        if self.kvman is not None:
            pt[:n] = self.kvman.rows([r.slot for r in actives])
        fn = self._decode_fn(b)
        t0 = time.perf_counter()
        nxt, self.cache, stats = fn(
            self.params, jnp.asarray(tokens), jnp.asarray(pos),
            jnp.asarray(slot_idx), jnp.asarray(pt), self.cache,
            self.routing)
        nxt = np.asarray(nxt)
        self.slo.step("decode", time.perf_counter() - t0)
        self.decode_steps += 1
        self._update_loads(stats)
        for i, r in enumerate(actives):
            tok = int(nxt[i])
            if not r.generated:
                self.slo.first_token(r.rid)
            else:
                self.slo.token(r.rid)
            r.generated.append(tok)
            r.pos += 1
            if (len(r.generated) >= r.max_new_tokens
                    or r.pos >= self.ecfg.max_len - 1):
                r.done = True
                self.slo.finish(r.rid)
                self.free_slots.append(r.slot)
                if self.kvman is not None:
                    self.kvman.release(r.slot)
                self.completed[r.rid] = r
                del self.active[r.rid]
        if (self.cfg.is_moe and self.ecfg.rebalance_every
                and self.decode_steps % self.ecfg.rebalance_every == 0):
            self.rebalance()

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    def step(self):
        """One engine iteration: admit -> wave prefill -> decode."""
        self.slo.queue_depth(len(self.queue))
        wave = self._admit()
        if wave:
            self._prefill_wave(wave)
        self._decode_all()

    def run(self, max_iters: int = 10_000):
        """Run until queue + active drain (or max_iters)."""
        it = 0
        while self.has_work and it < max_iters:
            self.step()
            it += 1
        return self.slo.summary()

    def finished_requests(self):
        return {rid: t for rid, t in self.slo.timings.items()
                if t.finished > 0}
