"""Continuous-batching serving engine with prefill/decode co-deployment.

The paper's real-system setting (§VI-A): prefill and decode co-deployed,
EPLB expert placement/replication as the fixed substrate, token routing
selectable per phase — METRO for the memory-bound decode phase, EPLB's
round-robin for prefill (exactly the paper's deployment).

Engine loop per iteration (vLLM/sarathi-style):
  1. admit waiting requests into free slots.  With chunked prefill a
     request only needs pages for its FIRST chunk to start, so admission
     scans past a page-blocked head request instead of head-of-line
     blocking the whole queue (``prefill_mode="wave"`` keeps the strict
     FCFS gate for A/B).
  2. plan this iteration's prefill work: every prefilling row advances
     by up to ``prefill_chunk`` tokens, capped globally by
     ``mixed_prefill_budget`` tokens per iteration (sarathi's token
     budget).  Chunks run against the PAGED serving cache directly —
     attention reads already-written pages, mamba carries {conv, h}
     state across calls — so a long prompt costs O(chunk) activations
     instead of O(max_len) and can be preempted between chunks.
  3. run the step: when ``mixed_steps`` and both phases have rows, ONE
     fused call executes the prefill chunks and the decode tokens
     together (decode no longer stalls behind prefill at all); otherwise
     the chunk call and the bucketed decode call run back-to-back and
     the chunk time is attributed as decode stall (``SLOTracker.stall``).
  4. retire finished requests; every ``rebalance_every`` decode steps,
     recompute EPLB placement from the observed expert-load EWMA and
     reshuffle the physical expert weights.

Every equivalence is pinned bit-for-bit by the test harness:
  * any chunk split == one monolithic chunk call (logits + KV pages),
    tests/test_chunked_prefill.py;
  * mixed fused step == pure-phase chunk-then-decode sequence
    (tokens + per-call expert_hist), tests/test_mixed_steps.py;
  * preempt-between-chunks + readmission == never-preempted run,
    tests/test_mixed_steps.py.

Batch-size bucketing mirrors the paper's CUDA-graph integration (§V):
step functions are jitted once per (bucket, padded-length) signature and
reused for every batch that rounds up to it; the ``SLOTracker`` counts
each fresh compile.  Chunk calls have ONE static token length
(``prefill_chunk``; short tails are masked per row), so chunked prefill
needs O(log max_batch) compiles total vs O(log max_batch · log max_len)
for wave prefill.

KV storage is paged by default (``kv_layout="paged"``): attention layers
share a flat pool of fixed-size pages (``serving/kv.py``), each sequence
owns only the pages its tokens occupy, and page tables are step *inputs*
— growing a sequence or admitting past the dense-residency limit never
recompiles.  When the pool runs dry the engine preempts the youngest
sequence (free its pages, requeue, recompute on readmission) — now also
*between prefill chunks*, so a half-prefilled long prompt can yield its
pages.  ``kv_layout="dense"`` keeps the seed's ``[max_batch, max_len]``
buffers for A/B comparison (dense implies ``prefill_mode="wave"``), and
``bucket_mode="fixed"`` + ``batch_prefill=False`` reproduces the seed
scheduler exactly.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import build_placement
from repro.models import lm as LM
from repro.serving.kv import PagedKVManager, pages_for
from repro.serving.slo import SLOTracker
from repro.sharding.policy import Dist


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [n] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0                # next position to fill
    n_ctx: int = 0              # context tokens to prefill (this admission)
    done: bool = False
    preempted: int = 0          # times evicted under page pressure
    preempted_in_prefill: int = 0   # of those, evictions between chunks

    def context_tokens(self) -> np.ndarray:
        """Tokens to (re)prefill: the prompt plus anything generated
        before a preemption (recompute-on-readmission)."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def prefilling(self) -> bool:
        return self.pos < self.n_ctx


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8          # decode slots
    max_len: int = 256          # KV capacity per sequence
    replication_ratio: float = 1.25
    decode_algo: str = "metro"  # the paper's technique
    prefill_algo: str = "eplb"
    rebalance_every: int = 64   # decode steps between EPLB rebalances
    load_ewma: float = 0.9
    prefill_chunk: int = 64     # tokens per prefill chunk
    greedy: bool = True
    seed: int = 0
    # --- scheduling ---
    bucket_mode: str = "pow2"   # "pow2" | "fixed" (seed: pad to max_batch)
    batch_prefill: bool = True  # (wave mode) pack the wave into one call
    max_wave: int = 0           # prefill wave cap; 0 -> max_batch
    bucket_compile_grace: int = 4   # steps a cold bucket rounds up to a
                                    # compiled one before earning its own
                                    # compile (0 = always compile exact)
    # --- chunked / mixed prefill ---
    prefill_mode: str = "chunked"   # "chunked" | "wave" (seed monolith)
    mixed_prefill_budget: int = 0   # max prefill tokens per iteration
                                    # (0 = every prefilling row advances
                                    # one full chunk per iteration)
    mixed_steps: bool = True        # fuse prefill chunks + decode into
                                    # one call when both phases have rows
    # --- KV layout ---
    kv_layout: str = "paged"    # "paged" | "dense" (seed layout)
    page_size: int = 16         # tokens per KV page
    num_pages: int = 0          # pool size; 0 -> full residency
                                #   (max_batch * ceil(max_len/page_size))


def _pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())


class ServingEngine:
    def __init__(self, cfg: ModelConfig, dist: Dist, params,
                 ecfg: EngineConfig, routing_table_width: int = 0):
        assert ecfg.bucket_mode in ("pow2", "fixed"), ecfg.bucket_mode
        assert ecfg.kv_layout in ("paged", "dense"), ecfg.kv_layout
        assert ecfg.prefill_mode in ("chunked", "wave"), ecfg.prefill_mode
        self.cfg = cfg
        self.dist = dist
        self.ecfg = ecfg
        self.params = params
        self.slo = SLOTracker()
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.completed: dict[int, Request] = {}
        self.free_slots = list(range(ecfg.max_batch))
        self.decode_steps = 0
        self.expert_loads = np.ones(max(cfg.num_experts, 1))
        self.expert_hist_log: list[np.ndarray] = []
        self._table_width = routing_table_width
        self._next_rid = 0
        # chunked prefill needs the paged pool (attention chunks resume
        # against already-written pages); dense layout keeps the seed's
        # monolithic wave path.
        self.chunked = (ecfg.prefill_mode == "chunked"
                        and ecfg.kv_layout == "paged")

        if cfg.is_moe:
            self.placement = build_placement(
                cfg.num_experts, dist.ep_size, dist.slots_per_device,
                loads=self.expert_loads)
            if not self._table_width:
                self._table_width = min(
                    dist.num_slots - cfg.num_experts + 1, dist.ep_size * 2)
                self._table_width = max(self._table_width,
                                        self.placement.max_replicas)
            self.routing = LM.build_lm_routing(cfg, self.placement,
                                               self._table_width)
            # logical master weights (for rebalance reshuffling)
            self._logical = self._extract_logical(params)
        else:
            self.placement, self.routing = None, {}

        if ecfg.kv_layout == "paged":
            pmax = pages_for(ecfg.max_len, ecfg.page_size)
            num_pages = ecfg.num_pages or ecfg.max_batch * pmax
            self.kvman: Optional[PagedKVManager] = PagedKVManager(
                num_pages=num_pages, page_size=ecfg.page_size,
                max_pages_per_seq=pmax, max_seqs=ecfg.max_batch)
            self.cache = LM.init_paged_cache(
                cfg, dist, num_pages, ecfg.page_size, ecfg.max_batch)
        else:
            self.kvman = None
            self.cache = LM.init_cache(cfg, dist, ecfg.max_batch,
                                       ecfg.max_len)
        self._fns: dict[str, dict] = {"decode": {}, "prefill": {},
                                      "chunk": {}, "mixed": {}}
        self._bucket_demand: dict[int, int] = {}

    # ------------------------------------------------------------------
    # weight reshuffling (EPLB rebalance)
    # ------------------------------------------------------------------
    def _extract_logical(self, params):
        """Logical expert master: replica 0 of each expert."""
        first_slot = np.array([
            self.placement.expert_slots[e, 0]
            for e in range(self.cfg.num_experts)])
        out = {}

        def grab(tree, path=()):
            for k, v in tree.items():
                if isinstance(v, dict):
                    grab(v, path + (k,))
                elif k in ("w_up", "w_down") and v.ndim >= 4:
                    out[path + (k,)] = np.asarray(v)[:, first_slot]
        grab(params["blocks"])
        return out

    def rebalance(self):
        """Recompute EPLB placement from observed loads + reshuffle."""
        if not self.cfg.is_moe:
            return
        self.placement = build_placement(
            self.cfg.num_experts, self.dist.ep_size,
            self.dist.slots_per_device, loads=self.expert_loads)
        self.routing = LM.build_lm_routing(self.cfg, self.placement,
                                           self._table_width)
        idx = self.placement.replica_expert

        def put(tree, path=()):
            for k, v in list(tree.items()):
                if isinstance(v, dict):
                    put(v, path + (k,))
                elif k in ("w_up", "w_down") and v.ndim >= 4:
                    tree[k] = jnp.asarray(self._logical[path + (k,)][:, idx])
        put(self.params["blocks"])

    # ------------------------------------------------------------------
    # step functions (compiled once per shape signature)
    # ------------------------------------------------------------------
    def _get_fn(self, kind: str, key, builder):
        fns = self._fns[kind]
        if key not in fns:
            fns[key] = builder()
            self.slo.compiled(kind, key)
        return fns[key]

    def _decode_fn(self, bucket: int):
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            paged = ecfg.kv_layout == "paged"

            @jax.jit
            def step(params, tokens, pos, slot_idx, page_table, cache,
                     routing):
                logits, new_cache, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, pos=pos, cache=cache,
                    routing=routing, mode="decode", algo=ecfg.decode_algo,
                    slot_idx=slot_idx,
                    page_table=page_table if paged else None,
                    row_valid=slot_idx < ecfg.max_batch)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, new_cache, stats
            return step
        return self._get_fn("decode", bucket, build)

    def _prefill_fn(self, batch: int, length: int):
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            paged = ecfg.kv_layout == "paged"

            @jax.jit
            def step(params, tokens, lengths, slot_idx, page_table, cache,
                     routing):
                wave = LM.init_wave_cache(cfg, dist, batch, length)
                _, filled, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, cache=wave,
                    routing=routing, mode="prefill",
                    algo=ecfg.prefill_algo, chunk=ecfg.prefill_chunk,
                    row_valid=jnp.arange(length)[None, :]
                    < lengths[:, None])
                new_cache = LM.merge_wave_cache(
                    cfg, cache, filled, slot_idx, lengths,
                    page_table=page_table if paged else None,
                    page_size=ecfg.page_size)
                return new_cache, stats
            return step
        return self._get_fn("prefill", (batch, length), build)

    def _chunk_fn(self, batch: int):
        """One resumable prefill chunk for ``batch`` rows: [B, C] tokens
        written straight into the paged serving cache (no wave scratch,
        no O(max_len) buffer — C = prefill_chunk is the only length)."""
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            c = ecfg.prefill_chunk

            @jax.jit
            def step(params, tokens, start, n_tok, slot_idx, page_table,
                     cache, routing):
                _, new_cache, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, pos=start,
                    cache=cache, routing=routing, mode="chunk_prefill",
                    algo=ecfg.prefill_algo, slot_idx=slot_idx,
                    page_table=page_table,
                    row_valid=jnp.arange(c)[None, :] < n_tok[:, None])
                return new_cache, stats
            return step
        return self._get_fn("chunk", batch, build)

    def _mixed_fn(self, bp: int, bd: int):
        """Fused mixed step: ``bp`` prefill-chunk rows and ``bd`` decode
        rows in ONE jitted call — the chunk sub-graph writes its pages,
        then the decode sub-graph runs against the updated cache, exactly
        the pure-phase chunk-then-decode sequence (bitwise: the
        equivalence test), but decode no longer waits for a dispatch."""
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            c = ecfg.prefill_chunk

            @jax.jit
            def step(params, p_tokens, p_start, p_ntok, p_slot, p_pt,
                     d_tokens, d_pos, d_slot, d_pt, cache, routing):
                _, cache1, st_p = LM.apply_lm(
                    cfg, dist, params, tokens=p_tokens, pos=p_start,
                    cache=cache, routing=routing, mode="chunk_prefill",
                    algo=ecfg.prefill_algo, slot_idx=p_slot,
                    page_table=p_pt,
                    row_valid=jnp.arange(c)[None, :] < p_ntok[:, None])
                logits, cache2, st_d = LM.apply_lm(
                    cfg, dist, params, tokens=d_tokens, pos=d_pos,
                    cache=cache1, routing=routing, mode="decode",
                    algo=ecfg.decode_algo, slot_idx=d_slot,
                    page_table=d_pt,
                    row_valid=d_slot < ecfg.max_batch)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, cache2, st_p, st_d
            return step
        return self._get_fn("mixed", (bp, bd), build)

    # ------------------------------------------------------------------
    # admission / paging
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        prompt = np.asarray(prompt, np.int32)
        assert len(prompt) < self.ecfg.max_len, (
            f"prompt of {len(prompt)} tokens exceeds max_len-1="
            f"{self.ecfg.max_len - 1}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, max_new_tokens))
        self.slo.arrive(rid, len(prompt))
        return rid

    def _admit(self) -> list[Request]:
        """Admit waiting requests into free slots.

        Chunked prefill only needs pages for a request's FIRST chunk, so
        a page-blocked request no longer blocks the whole queue: the
        scan continues past it and admits any later request that fits
        (slots stay strictly FCFS — running out of slots stops the
        scan).  ``prefill_mode="wave"`` needs every context page up
        front and keeps the seed's strict head-of-line gate.
        """
        admitted: list[Request] = []
        if not self.queue or not self.free_slots:
            return admitted
        remaining: deque[Request] = deque()    # page-blocked, scanned past
        while self.queue and self.free_slots:
            r = self.queue.popleft()
            n_ctx = min(len(r.context_tokens()), self.ecfg.max_len - 1)
            first = min(n_ctx, self.ecfg.prefill_chunk) if self.chunked \
                else n_ctx
            if self.kvman is not None and \
                    pages_for(first, self.ecfg.page_size) \
                    > self.kvman.num_free:
                remaining.append(r)
                if not self.chunked:
                    break               # strict FCFS: wait for pages
                continue
            r.slot = self.free_slots.pop()
            r.n_ctx = n_ctx
            r.pos = 0
            if self.kvman is not None:
                ok = self.kvman.ensure(r.slot, first)
                assert ok, "admission page reservation failed"
            self.active[r.rid] = r
            admitted.append(r)
            self.slo.admitted(r.rid)
        # splice the untouched tail back (skipped requests were earlier
        # in the queue, so relative order is preserved); O(1) when the
        # scan never started
        remaining.extend(self.queue)
        self.queue = remaining
        return admitted

    def _preempt_one(self, protect_rid: int) -> bool:
        """Evict the youngest active request (≠ protect_rid): free its
        pages + slot and requeue it for recompute-on-readmission.  A
        victim caught *between prefill chunks* releases every page it
        has written so far; readmission recomputes bitwise to the state
        an unpreempted run would have reached (the prefill-phase
        regression test).  A victim caught mid-DECODE replays
        prompt+generated as context, which collapses the re-fed
        boundary token the continued run kept at position n_ctx — its
        continuation is correct-by-recompute but not bitwise the
        unpreempted one (seed semantics, unchanged)."""
        victims = [r for r in self.active.values() if r.rid != protect_rid]
        if not victims:
            return False
        v = max(victims, key=lambda r: r.rid)
        if v.prefilling:
            v.preempted_in_prefill += 1
        self.kvman.release(v.slot)
        self.free_slots.append(v.slot)
        del self.active[v.rid]
        v.slot, v.pos, v.n_ctx, v.preempted = -1, 0, 0, v.preempted + 1
        self.queue.appendleft(v)
        self.slo.preemptions += 1
        return True

    def _reserve(self, targets: list[tuple[Request, int]]):
        """Grow each target row's page table to cover ``want`` tokens,
        preempting the youngest other sequences under pool pressure.
        Oldest targets reserve first; a target that was itself evicted
        by an earlier reservation is skipped."""
        if self.kvman is None:
            return
        for r, want in sorted(targets, key=lambda t: t[0].rid):
            if r.rid not in self.active:
                continue
            want = min(want, self.ecfg.max_len)
            while not self.kvman.ensure(r.slot, want):
                if not self._preempt_one(protect_rid=r.rid):
                    raise RuntimeError(
                        "KV page pool exhausted by a single sequence; "
                        "num_pages must be >= ceil(max_len/page_size)")

    # ------------------------------------------------------------------
    # prefill — monolithic wave path (prefill_mode="wave" / dense KV)
    # ------------------------------------------------------------------
    def _prefill_wave(self, wave: list[Request]):
        group_cap = (self.ecfg.max_wave or self.ecfg.max_batch) \
            if self.ecfg.batch_prefill else 1
        for i in range(0, len(wave), group_cap):
            self._prefill_group(wave[i:i + group_cap])

    def _prefill_group(self, group: list[Request]):
        ecfg = self.ecfg
        ctxs = [r.context_tokens() for r in group]
        lens = [min(len(c), ecfg.max_len - 1) for c in ctxs]
        b = _pow2(len(group))
        l_pad = min(max(_pow2(max(lens)), 8), ecfg.max_len)
        pmax = pages_for(ecfg.max_len, ecfg.page_size)
        toks = np.zeros((b, l_pad), np.int32)
        lengths = np.zeros((b,), np.int32)
        slot_idx = np.full((b,), ecfg.max_batch, np.int32)  # OOB = pad row
        pt = np.full((b, pmax), -1, np.int32)
        for i, r in enumerate(group):
            toks[i, :lens[i]] = ctxs[i][:lens[i]]
            lengths[i] = lens[i]
            slot_idx[i] = r.slot
            self.slo.prefill_started(r.rid)
        if self.kvman is not None:
            pt[:len(group)] = self.kvman.rows([r.slot for r in group])
        fn = self._prefill_fn(b, l_pad)
        t0 = time.perf_counter()
        self.cache, stats = fn(
            self.params, jnp.asarray(toks), jnp.asarray(lengths),
            jnp.asarray(slot_idx), jnp.asarray(pt), self.cache,
            self.routing)
        jax.block_until_ready(stats)
        dt = time.perf_counter() - t0
        self.slo.step("prefill", dt)
        gids = {r.rid for r in group}
        if any(not r.prefilling for r in self.active.values()
               if r.rid not in gids):
            self.slo.stall("prefill", dt)
        for r, n in zip(group, lens):
            r.pos = n
            self.slo.chunk_done(r.rid)
            self.slo.prefill_done(r.rid)
        self._update_loads(stats)

    # ------------------------------------------------------------------
    # prefill — resumable chunked path (the default)
    # ------------------------------------------------------------------
    def _plan_chunks(self) -> list[tuple[Request, int]]:
        """Pick this iteration's prefill work: each prefilling row gets
        up to one ``prefill_chunk`` of its remaining context, FCFS by
        rid, capped globally by ``mixed_prefill_budget`` tokens (0 = no
        cap).  Partial chunks are free — the chunk call has one static
        shape and masks per-row tails."""
        budget = self.ecfg.mixed_prefill_budget or None
        work: list[tuple[Request, int]] = []
        for r in sorted(self.active.values(), key=lambda r: r.rid):
            if not r.prefilling:
                continue
            n = min(r.n_ctx - r.pos, self.ecfg.prefill_chunk)
            if budget is not None:
                n = min(n, budget)
                if n <= 0:
                    break
                budget -= n
            work.append((r, n))
        return work

    def _chunk_inputs(self, pwork: list[tuple[Request, int]], b: int):
        ecfg = self.ecfg
        c = ecfg.prefill_chunk
        pmax = pages_for(ecfg.max_len, ecfg.page_size)
        toks = np.zeros((b, c), np.int32)
        start = np.zeros((b,), np.int32)
        n_tok = np.zeros((b,), np.int32)
        slot_idx = np.full((b,), ecfg.max_batch, np.int32)
        pt = np.full((b, pmax), -1, np.int32)
        for i, (r, n) in enumerate(pwork):
            ctx = r.context_tokens()
            toks[i, :n] = ctx[r.pos:r.pos + n]
            start[i] = r.pos
            n_tok[i] = n
            slot_idx[i] = r.slot
        pt[:len(pwork)] = self.kvman.rows([r.slot for r, _ in pwork])
        return (jnp.asarray(toks), jnp.asarray(start), jnp.asarray(n_tok),
                jnp.asarray(slot_idx), jnp.asarray(pt))

    def _decode_inputs(self, drows: list[Request], b: int):
        ecfg = self.ecfg
        pmax = pages_for(ecfg.max_len, ecfg.page_size)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        slot_idx = np.full((b,), ecfg.max_batch, np.int32)
        pt = np.full((b, pmax), -1, np.int32)
        for i, r in enumerate(drows):
            tokens[i, 0] = (r.generated[-1] if r.generated
                            else int(r.context_tokens()[-1]))
            # a row finishing its prefill THIS iteration decodes at
            # n_ctx (its r.pos advances when the chunk completes); an
            # already-decoding row is simply at r.pos.  (n_ctx +
            # len(generated) would be wrong after a mid-decode
            # preemption: the re-prefilled n_ctx already contains the
            # generated tokens.)
            pos[i] = r.n_ctx if r.prefilling else r.pos
            slot_idx[i] = r.slot
        if self.kvman is not None:
            pt[:len(drows)] = self.kvman.rows([r.slot for r in drows])
        return (jnp.asarray(tokens), jnp.asarray(pos),
                jnp.asarray(slot_idx), jnp.asarray(pt))

    def _start_chunks(self, pwork: list[tuple[Request, int]]):
        """Stamp prefill_start BEFORE the chunk-carrying call is issued
        (the wave path does the same), so the first chunk's time lands
        in the TTFT prefill span, not the queue wait."""
        for r, _ in pwork:
            if r.pos == 0:
                self.slo.prefill_started(r.rid)

    def _finish_chunks(self, pwork: list[tuple[Request, int]]):
        for r, n in pwork:
            r.pos += n
            self.slo.chunk_done(r.rid)
            if not r.prefilling:
                self.slo.prefill_done(r.rid)

    def _postprocess_decode(self, drows: list[Request], nxt: np.ndarray):
        for i, r in enumerate(drows):
            tok = int(nxt[i])
            if not r.generated:
                self.slo.first_token(r.rid)
            else:
                self.slo.token(r.rid)
            r.generated.append(tok)
            r.pos += 1
            if (len(r.generated) >= r.max_new_tokens
                    or r.pos >= self.ecfg.max_len - 1):
                r.done = True
                self.slo.finish(r.rid)
                self.free_slots.append(r.slot)
                if self.kvman is not None:
                    self.kvman.release(r.slot)
                self.completed[r.rid] = r
                del self.active[r.rid]
        self.decode_steps += 1
        if (self.cfg.is_moe and self.ecfg.rebalance_every
                and self.decode_steps % self.ecfg.rebalance_every == 0):
            self.rebalance()

    # per-call expert_hist log (equivalence tests); bounded so a
    # long-running engine doesn't grow it without limit
    _HIST_LOG_CAP = 8192

    def _update_loads(self, stats):
        if not self.cfg.is_moe:
            return
        h = np.asarray(stats["expert_hist"])
        if h.shape[0] == self.cfg.num_experts:
            self.expert_hist_log.append(h)
            if len(self.expert_hist_log) > self._HIST_LOG_CAP:
                del self.expert_hist_log[:self._HIST_LOG_CAP // 2]
            a = self.ecfg.load_ewma
            self.expert_loads = a * self.expert_loads + (1 - a) * (h + 1e-3)

    # ------------------------------------------------------------------
    # decode (bucketed)
    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        """Decode batch bucket for n active sequences.

        Power-of-two rounding, with a compile-avoidance grace: a bucket
        nobody has compiled yet first borrows the smallest compiled
        bucket above it (correct — extra rows are padding) and only
        earns its own compile after ``bucket_compile_grace`` uses.  This
        keeps end-of-trace drain-down from compiling each small bucket
        for a handful of steps, while sustained low occupancy (a long
        low-rate phase, a straggler tail) still gets its fast bucket.
        """
        if self.ecfg.bucket_mode == "fixed":
            return self.ecfg.max_batch
        b = min(_pow2(max(n, 1)), self.ecfg.max_batch)
        fns = self._fns["decode"]
        if b in fns:
            return b
        bigger = [k for k in fns if k > b]
        if not bigger:
            return b
        self._bucket_demand[b] = self._bucket_demand.get(b, 0) + 1
        if self._bucket_demand[b] > self.ecfg.bucket_compile_grace:
            return b
        return min(bigger)

    def _decode_rows(self, drows: list[Request]):
        if not drows:
            return
        n = len(drows)
        b = self._bucket(n)
        tokens, pos, slot_idx, pt = self._decode_inputs(drows, b)
        fn = self._decode_fn(b)
        t0 = time.perf_counter()
        nxt, self.cache, stats = fn(
            self.params, tokens, pos, slot_idx, pt, self.cache,
            self.routing)
        nxt = np.asarray(nxt)
        self.slo.step("decode", time.perf_counter() - t0)
        self._update_loads(stats)
        self._postprocess_decode(drows, nxt)

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    def step(self):
        """One engine iteration."""
        self.slo.queue_depth(len(self.queue))
        admitted = self._admit()
        if not self.chunked:
            # seed scheduler: monolithic wave prefill, then decode all
            if admitted:
                self._prefill_wave(admitted)
            self._reserve([(r, min(r.pos + 1, self.ecfg.max_len))
                           for r in self.active.values()])
            self._decode_rows(sorted(self.active.values(),
                                     key=lambda r: r.slot))
            return
        self._step_chunked()

    def _step_chunked(self):
        ecfg = self.ecfg
        pwork = self._plan_chunks()
        # decode set: rows already decoding, plus rows whose prefill
        # completes with this iteration's chunk (they re-feed their last
        # context token at position n_ctx, same as the wave scheduler)
        finishing = {r.rid for r, n in pwork if r.pos + n >= r.n_ctx}
        targets = [(r, r.pos + n + (1 if r.rid in finishing else 0))
                   for r, n in pwork]
        targets += [(r, r.pos + 1) for r in self.active.values()
                    if not r.prefilling]
        self._reserve(targets)     # may preempt scheduled rows: filter
        pwork = [(r, n) for r, n in pwork if r.rid in self.active]
        finishing = {r.rid for r, n in pwork if r.pos + n >= r.n_ctx}
        drows = [r for r in self.active.values()
                 if not r.prefilling or r.rid in finishing]
        drows.sort(key=lambda r: r.slot)

        if pwork and drows and ecfg.mixed_steps:
            self._mixed_step(pwork, drows)
            return
        if pwork:
            bp = _pow2(len(pwork))
            self._start_chunks(pwork)
            toks, start, n_tok, slot_idx, pt = self._chunk_inputs(pwork, bp)
            fn = self._chunk_fn(bp)
            t0 = time.perf_counter()
            self.cache, stats = fn(self.params, toks, start, n_tok,
                                   slot_idx, pt, self.cache, self.routing)
            jax.block_until_ready(stats)
            dt = time.perf_counter() - t0
            self.slo.step("chunk", dt)
            if any(r.rid not in finishing for r in drows):
                # pure-phase mode: PRE-EXISTING decode rows sat out the
                # chunk call (rows finishing prefill in this very call
                # were not waiting on anything)
                self.slo.stall("chunk", dt)
            self._update_loads(stats)
            self._finish_chunks(pwork)
        self._decode_rows(drows)

    def _mixed_step(self, pwork: list[tuple[Request, int]],
                    drows: list[Request]):
        """Sarathi-style piggybacked iteration: ONE call runs the chunk
        tokens and the decode tokens, so decode rows never stall behind
        prefill (no ``slo.stall`` is recorded — there is nothing to
        wait for)."""
        bp = _pow2(len(pwork))
        bd = self._bucket(len(drows))
        self._start_chunks(pwork)
        p_toks, p_start, p_ntok, p_slot, p_pt = \
            self._chunk_inputs(pwork, bp)
        # decode inputs are computed AFTER the chunk advances each
        # finishing row, so build them from the planned post-chunk state
        d_toks, d_pos, d_slot, d_pt = self._decode_inputs(drows, bd)
        fn = self._mixed_fn(bp, bd)
        t0 = time.perf_counter()
        nxt, self.cache, st_p, st_d = fn(
            self.params, p_toks, p_start, p_ntok, p_slot, p_pt,
            d_toks, d_pos, d_slot, d_pt, self.cache, self.routing)
        nxt = np.asarray(nxt)
        self.slo.step("mixed", time.perf_counter() - t0)
        # same update order as the pure-phase sequence it replaces
        self._update_loads(st_p)
        self._update_loads(st_d)
        self._finish_chunks(pwork)
        self._postprocess_decode(drows, nxt)

    def run(self, max_iters: int = 10_000):
        """Run until queue + active drain (or max_iters)."""
        it = 0
        while self.has_work and it < max_iters:
            self.step()
            it += 1
        return self.slo.summary()

    def finished_requests(self):
        return {rid: t for rid, t in self.slo.timings.items()
                if t.finished > 0}
