"""Continuous-batching serving engine: thin façade over the layered
serving stack.

The paper's real-system setting (§VI-A): prefill and decode co-deployed,
EPLB expert placement/replication as the fixed substrate, token routing
selectable per phase — METRO for the memory-bound decode phase, EPLB's
round-robin for prefill (exactly the paper's deployment).

The engine is decomposed into three layers this module only wires
together (see serving/README.md for the full diagram):

  * :mod:`repro.serving.state`      — ``Request`` + ``EngineState``:
    admission queue, slot/page residency, expert-load EWMA.  No policy.
  * :mod:`repro.serving.scheduler`  — ``Scheduler``: admission with
    skip-ahead, chunk planning under the token budget, preemption,
    pow2 bucket policy with compile grace, and the rebalance window
    (deferred while chunked prefills are in flight).  No jax.
  * :mod:`repro.serving.executor`   — ``Executor``: the jit cache and
    decode/prefill/chunk/mixed step builders, input packing, the KV
    cache pytree (``kv_dtype``: bf16/fp32/fp8 paged pools), the CoW
    page copy, and the EPLB placement + routing tables + logical
    master weights the rebalance loop reshuffles.  No scheduling.

Below state sits the paged-KV substrate: :mod:`repro.serving.kv`
(refcounted pages) and :mod:`repro.serving.prefix` (the shared-prefix
radix cache — ``enable_prefix_cache``): admission starts a request's
prefill at its longest cached prefix, sharing full pages read-only and
copy-on-writing the boundary page; a prefix-hit request's tokens and
logical KV are bitwise the cold run's (tests/test_prefix_cache.py).

:class:`ServingEngine` keeps the public surface of the former monolith
(``submit`` / ``step`` / ``run``, plus ``queue`` / ``active`` /
``completed`` / ``kvman`` / ``cache`` / ``free_slots`` delegations), so
every PR-2 equivalence suite runs unmodified against the refactor.  One
engine is one replica; :mod:`repro.serving.cluster` runs N of them
behind a router with a shared EPLB placement.

Engine loop per iteration (vLLM/sarathi-style):
  1. admit waiting requests into free slots (skip-ahead past a
     page-blocked head request under chunked prefill).
  2. plan this iteration's prefill chunks (``prefill_chunk`` per row,
     ``mixed_prefill_budget`` global token cap).
  3. run the step: ONE fused mixed call when both phases have rows and
     ``mixed_steps``; otherwise chunk call + bucketed decode call
     back-to-back with the chunk time attributed as decode stall.
  4. retire finished requests; when the rebalance window fires (and no
     chunked prefill is in flight), recompute EPLB placement from the
     observed expert-load EWMA and reshuffle the physical weights.

Every equivalence is pinned bit-for-bit by the test harness:
  * any chunk split == one monolithic chunk call (logits + KV pages),
    tests/test_chunked_prefill.py;
  * mixed fused step == pure-phase chunk-then-decode sequence
    (tokens + per-call expert_hist), tests/test_mixed_steps.py;
  * preempt-between-chunks + readmission == never-preempted run,
    tests/test_mixed_steps.py;
  * rebalance mid-prefill == no rebalance at all (tokens + hist),
    tests/test_cluster.py;
  * single-replica ClusterEngine == bare ServingEngine,
    tests/test_cluster.py.

Timing is injectable for cluster simulation: pass a
:class:`repro.serving.slo.VirtualClock` plus a ``step_cost(kind,
n_tokens, stats) -> seconds`` model and every step advances virtual
time by the modeled cost (decode cost driven by ``max_activated`` — the
paper's memory-bound quantity) instead of wall time, making
multi-replica SLO sweeps bit-reproducible on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.executor import Executor
from repro.serving.scheduler import Scheduler, _pow2
from repro.serving.slo import SLOTracker, VirtualClock
from repro.serving.state import EngineState, Request
from repro.sharding.policy import Dist

__all__ = ["EngineConfig", "ServingEngine", "Request"]


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8          # decode slots
    max_len: int = 256          # KV capacity per sequence
    replication_ratio: float = 1.25
    decode_algo: str = "metro"  # the paper's technique
    prefill_algo: str = "eplb"
    rebalance_every: int = 64   # decode steps between EPLB rebalances
    rebalance_defer_prefill: bool = True    # hold a due rebalance until
                                # no chunked prefill is in flight
                                # (bounded: forced after one extra
                                # window so load can't starve it)
    load_ewma: float = 0.9
    prefill_chunk: int = 64     # tokens per prefill chunk
    greedy: bool = True
    seed: int = 0
    # --- scheduling ---
    bucket_mode: str = "pow2"   # "pow2" | "fixed" (seed: pad to max_batch)
    batch_prefill: bool = True  # (wave mode) pack the wave into one call
    max_wave: int = 0           # prefill wave cap; 0 -> max_batch
    bucket_compile_grace: int = 4   # steps a cold bucket rounds up to a
                                    # compiled one before earning its own
                                    # compile (0 = always compile exact)
    # --- chunked / mixed prefill ---
    prefill_mode: str = "chunked"   # "chunked" | "wave" (seed monolith)
    mixed_prefill_budget: int = 0   # max prefill tokens per iteration
                                    # (0 = every prefilling row advances
                                    # one full chunk per iteration)
    mixed_steps: bool = True        # fuse prefill chunks + decode into
                                    # one call when both phases have rows
    # --- KV layout ---
    kv_layout: str = "paged"    # "paged" | "dense" (seed layout)
    page_size: int = 16         # tokens per KV page
    num_pages: int = 0          # pool size; 0 -> full residency
                                #   (max_batch * ceil(max_len/page_size))
    kv_dtype: str = "bf16"      # paged pool element type: "bf16" |
                                # "fp32" | "fp8" (fp8 halves KV residency;
                                # paged reads dequantize in-path — paged
                                # layout only)
    # --- prefix cache (shared-prefix KV reuse) ---
    enable_prefix_cache: bool = False   # radix prefix index over the
                                # paged pool + copy-on-write boundary
                                # pages (chunked+paged only; mamba-
                                # bearing archs auto-disable — SSM state
                                # is not paged)
    prefix_min_tokens: int = 1  # shortest cached match worth taking
                                # (a 1-token hit still costs a CoW copy)
    admit_reserve_frac: float = 0.0     # page-aware admission headroom:
                                # fraction of a request's future page
                                # demand held back, decayed by queue
                                # depth (0 = PR-2's plain first-chunk
                                # gate)
    # --- kernels ---
    use_flash_kernel: bool = False  # paged decode attention through the
                                    # Pallas flash_decode_paged kernel
                                    # (full-attention layers; SWA keeps
                                    # the gather reference)
    moe_impl: str = "ragged"    # grouped expert-FFN datapath:
                                # "ragged" | "scan_tiles" | "onehot" |
                                # "pallas" (two-pass Pallas kernel) |
                                # "fused" (one-pass up→act→down Pallas
                                # megakernel, hidden stays in VMEM) |
                                # "fused_paged" (fused + explicit
                                # double-buffered weight DMA from a
                                # frame pool) — see kernels/README.md
    use_pallas_route: bool = False  # METRO Alg. 1 greedy routing on the
                                    # Pallas scalar-core kernel instead
                                    # of the lax.scan reference
    # --- expert-weight paging (MoE models bigger than HBM) ---
    expert_pool: bool = False   # page per-(layer, slot) expert weights
                                # between a host backing store and a
                                # bounded HBM frame pool, with
                                # activation-aware prefetch from the
                                # router's previous step (MoE archs
                                # only; ignored otherwise)
    hbm_budget_bytes: int = 0   # expert-weight HBM budget per replica;
                                # 0 = every page resident (compulsory
                                # misses only).  Floored at one layer's
                                # slot set — the activated working set
                                # a single layer pins
    prefetch_depth: int = 8     # pages the prefetcher may fetch
                                # overlapped per step; the rest of the
                                # plan waits for the decode residency
                                # gate (attributed as decode stall)
    pool_h2d_bw: float = 1.6e10     # modeled host->HBM bandwidth
                                # (bytes/s) for miss/gate stall
                                # attribution and the roofline model


class ServingEngine:
    def __init__(self, cfg: ModelConfig, dist: Dist, params,
                 ecfg: EngineConfig, routing_table_width: int = 0,
                 clock: Optional[VirtualClock] = None,
                 step_cost: Optional[Callable] = None,
                 fn_cache: Optional[dict] = None):
        assert ecfg.bucket_mode in ("pow2", "fixed"), ecfg.bucket_mode
        assert ecfg.kv_layout in ("paged", "dense"), ecfg.kv_layout
        assert ecfg.prefill_mode in ("chunked", "wave"), ecfg.prefill_mode
        assert ecfg.kv_dtype in ("bf16", "fp32", "fp8"), ecfg.kv_dtype
        assert ecfg.moe_impl in ("ragged", "scan_tiles", "onehot",
                                 "pallas", "fused",
                                 "fused_paged"), ecfg.moe_impl
        assert ecfg.hbm_budget_bytes >= 0 and ecfg.prefetch_depth >= 0
        assert ecfg.kv_dtype == "bf16" or ecfg.kv_layout == "paged", \
            "kv_dtype plumbing is paged-path only"
        self.cfg = cfg
        self.dist = dist
        self.ecfg = ecfg
        self._vclock = clock
        self.step_cost = step_cost
        assert step_cost is None or clock is not None, \
            "a step_cost model needs a VirtualClock to advance"
        self.slo = SLOTracker(clock=clock.now if clock else None)
        # chunked prefill needs the paged pool (attention chunks resume
        # against already-written pages); dense layout keeps the seed's
        # monolithic wave path.
        self.chunked = (ecfg.prefill_mode == "chunked"
                        and ecfg.kv_layout == "paged")
        # prefix reuse needs resumable chunked prefill over the paged
        # pool, and every mixer's state must live in pages — mamba's
        # per-slot SSM state can't be rejoined at an arbitrary match
        # point, so mamba-bearing archs auto-disable (documented in
        # serving/prefix.py)
        self.prefix_enabled = bool(
            ecfg.enable_prefix_cache and self.chunked
            and cfg.family != "encdec"
            and all(mixer != "mamba" for mixer, _ in cfg.layer_kinds()))
        self.state = EngineState(ecfg, cfg.num_experts,
                                 prefix_enabled=self.prefix_enabled)
        self.exec = Executor(cfg, dist, ecfg, params, self.slo,
                             routing_table_width, fn_cache=fn_cache)
        self.sched = Scheduler(ecfg, self.state, self.slo, self.chunked,
                               copy_pages=self.exec.run_copy_pages)

    # ------------------------------------------------------------------
    # state / executor delegation (the monolith's public surface)
    # ------------------------------------------------------------------
    @property
    def queue(self):
        return self.state.queue

    @property
    def active(self):
        return self.state.active

    @property
    def completed(self):
        return self.state.completed

    @property
    def free_slots(self):
        return self.state.free_slots

    @property
    def kvman(self):
        return self.state.kvman

    @property
    def prefix_index(self):
        return self.state.prefix

    @property
    def expert_pool(self):
        return self.exec.expert_pool

    @property
    def decode_steps(self):
        return self.state.decode_steps

    @property
    def expert_loads(self):
        return self.state.expert_loads

    @property
    def expert_hist_log(self):
        return self.state.expert_hist_log

    @property
    def _next_rid(self):
        return self.state.next_rid

    @property
    def cache(self):
        return self.exec.cache

    @property
    def params(self):
        return self.exec.params

    @property
    def routing(self):
        return self.exec.routing

    @property
    def placement(self):
        return self.exec.placement

    @property
    def _fns(self):
        return self.exec._fns

    @property
    def has_work(self) -> bool:
        return self.state.has_work

    def _admit(self):
        return self.sched.admit()

    def prefix_match_len(self, prompt: np.ndarray) -> int:
        """Longest *takeable* cached prefix of ``prompt`` (0 when the
        cache is off or the match is below admission's eligibility bar
        — one shared definition, ``Scheduler.eligible_match``, so
        dispatch can never chase a match admission would refuse) — the
        cluster's prefix-affinity signal.  Pure peek: no LRU update."""
        m = self.sched.eligible_match(prompt)
        return m.m if m is not None else 0

    def _preempt_one(self, protect_rid: int) -> bool:
        return self.sched.preempt_one(protect_rid)

    # ------------------------------------------------------------------
    # virtual time
    # ------------------------------------------------------------------
    def advance_clock_to(self, t: float):
        """Jump an idle replica's virtual clock forward (a server that
        sat idle until an arrival starts working at the arrival time)."""
        if self._vclock is not None:
            self._vclock.t = max(self._vclock.t, t)

    def _charge(self, parts, wall_dt: float) -> float:
        """Convert one engine call into seconds.  Wall time by default;
        under a VirtualClock + step_cost model, the modeled cost of each
        (kind, n_tokens, stats) component, with the clock advanced."""
        if self._vclock is None or self.step_cost is None:
            return wall_dt
        dt = 0.0
        for kind, n_tok, stats in parts:
            dt += self.step_cost(kind, n_tok, {
                k: float(np.asarray(stats.get(k, 0.0)))
                for k in ("max_activated", "mean_activated",
                          "max_tokens", "pool_miss_bytes",
                          "pool_prefetch_bytes", "pool_gate_bytes")})
        self._vclock.advance(dt)
        return dt

    def _pool_slo(self, stats, decode: bool):
        """Fold one engine call's expert-pool hit/miss split into the
        SLO tracker.  Demand-miss bytes on a decode-carrying call are
        a decode stall (the step waited for the fetch); prefetch bytes
        are overlapped and gate bytes were already attributed by the
        scheduler's residency gate."""
        pool = self.exec.expert_pool
        if pool is None or "pool_hits" not in stats:
            return
        miss_b = float(stats.get("pool_miss_bytes", 0.0))
        self.slo.expert_pool_access(
            hits=int(stats["pool_hits"]),
            misses=int(stats["pool_misses"]),
            planned_hits=int(stats["pool_planned_hits"]),
            stall_s=(pool.stall_seconds(miss_b)
                     if decode and miss_b else 0.0))

    # ------------------------------------------------------------------
    # rebalance (EPLB placement + physical weight reshuffle)
    # ------------------------------------------------------------------
    def rebalance(self, placement=None):
        """Recompute EPLB placement from observed loads + reshuffle —
        or install a cluster-shared ``placement`` as-is."""
        self.exec.rebalance(self.state.expert_loads, placement=placement)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival: Optional[float] = None) -> int:
        """Queue a request.  ``arrival`` back-stamps the arrival time on
        the SLO timeline (virtual-time cluster replay submits at the
        trace arrival, which may precede the replica's local clock)."""
        r = self.state.new_request(prompt, max_new_tokens)
        self.slo.arrive(r.rid, len(r.prompt), at=arrival)
        return r.rid

    # ------------------------------------------------------------------
    # engine iteration
    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration."""
        self.slo.queue_depth(len(self.state.queue))
        admitted = self.sched.admit()
        if not self.chunked:
            # seed scheduler: monolithic wave prefill, then decode all
            if admitted:
                self._prefill_wave(admitted)
            self.sched.reserve(
                [(r, min(r.pos + 1, self.ecfg.max_len))
                 for r in self.state.active.values()])
            self._decode_rows(sorted(self.state.active.values(),
                                     key=lambda r: r.slot))
            return
        self._step_chunked()

    def _step_chunked(self):
        st = self.state
        pwork = self.sched.plan_chunks()
        # decode set: rows already decoding, plus rows whose prefill
        # completes with this iteration's chunk (they re-feed their last
        # context token at position n_ctx, same as the wave scheduler)
        finishing = {r.rid for r, n in pwork if r.pos + n >= r.n_ctx}
        targets = [(r, r.pos + n + (1 if r.rid in finishing else 0))
                   for r, n in pwork]
        targets += [(r, r.pos + 1) for r in st.active.values()
                    if not r.prefilling]
        self.sched.reserve(targets)    # may preempt scheduled rows: filter
        pwork = [(r, n) for r, n in pwork if r.rid in st.active]
        finishing = {r.rid for r, n in pwork if r.pos + n >= r.n_ctx}
        drows = [r for r in st.active.values()
                 if not r.prefilling or r.rid in finishing]
        drows.sort(key=lambda r: r.slot)

        if pwork and drows and self.ecfg.mixed_steps:
            self._mixed_step(pwork, drows)
            return
        if pwork:
            bp = _pow2(len(pwork))
            self._start_chunks(pwork)
            stats, wall = self.exec.run_chunk(pwork, bp, st.kvman)
            dt = self._charge(
                [("chunk", sum(n for _, n in pwork), stats)], wall)
            self.slo.step("chunk", dt)
            if any(r.rid not in finishing for r in drows):
                # pure-phase mode: PRE-EXISTING decode rows sat out the
                # chunk call (rows finishing prefill in this very call
                # were not waiting on anything)
                self.slo.stall("chunk", dt)
            self._update_loads(stats)
            self._pool_slo(stats, decode=False)
            self._finish_chunks(pwork)
        self._decode_rows(drows)

    def _mixed_step(self, pwork: list[tuple[Request, int]],
                    drows: list[Request]):
        """Sarathi-style piggybacked iteration: ONE call runs the chunk
        tokens and the decode tokens, so decode rows never stall behind
        prefill (no ``slo.stall`` is recorded — there is nothing to
        wait for)."""
        bp = _pow2(len(pwork))
        bd = self.sched.bucket(len(drows),
                               self.exec.compiled_buckets("decode"))
        gate_b = self.sched.gate_decode(self.exec.expert_pool)
        self._start_chunks(pwork)
        nxt, st_p, st_d, wall = self.exec.run_mixed(
            pwork, drows, bp, bd, self.state.kvman)
        if gate_b:
            st_d = dict(st_d, pool_gate_bytes=float(gate_b))
        dt = self._charge(
            [("chunk", sum(n for _, n in pwork), st_p),
             ("decode", len(drows), st_d)], wall)
        self.slo.step("mixed", dt)
        # same update order as the pure-phase sequence it replaces
        self._update_loads(st_p)
        self._update_loads(st_d)
        self._pool_slo(st_p, decode=False)
        self._pool_slo(st_d, decode=True)
        self._finish_chunks(pwork)
        self._postprocess_decode(drows, nxt)

    def _decode_rows(self, drows: list[Request]):
        if not drows:
            return
        b = self.sched.bucket(len(drows),
                              self.exec.compiled_buckets("decode"))
        gate_b = self.sched.gate_decode(self.exec.expert_pool)
        nxt, stats, wall = self.exec.run_decode(drows, b,
                                                self.state.kvman)
        if gate_b:
            stats = dict(stats, pool_gate_bytes=float(gate_b))
        dt = self._charge([("decode", len(drows), stats)], wall)
        self.slo.step("decode", dt)
        self._update_loads(stats)
        self._pool_slo(stats, decode=True)
        self._postprocess_decode(drows, nxt)

    # ------------------------------------------------------------------
    # prefill — monolithic wave path (prefill_mode="wave" / dense KV)
    # ------------------------------------------------------------------
    def _prefill_wave(self, wave: list[Request]):
        group_cap = (self.ecfg.max_wave or self.ecfg.max_batch) \
            if self.ecfg.batch_prefill else 1
        for i in range(0, len(wave), group_cap):
            self._prefill_group(wave[i:i + group_cap])

    def _prefill_group(self, group: list[Request]):
        lens = [min(len(r.context_tokens()), self.ecfg.max_len - 1)
                for r in group]
        for r in group:
            self.slo.prefill_started(r.rid)
        stats, wall = self.exec.run_wave(group, lens, self.state.kvman)
        dt = self._charge([("prefill", sum(lens), stats)], wall)
        self.slo.step("prefill", dt)
        gids = {r.rid for r in group}
        if any(not r.prefilling for r in self.state.active.values()
               if r.rid not in gids):
            self.slo.stall("prefill", dt)
        for r, n in zip(group, lens):
            r.pos = n
            self.slo.chunk_done(r.rid)
            self.slo.prefill_done(r.rid)
        self._update_loads(stats)
        self._pool_slo(stats, decode=False)

    # ------------------------------------------------------------------
    # chunk bookkeeping
    # ------------------------------------------------------------------
    def _start_chunks(self, pwork: list[tuple[Request, int]]):
        """Stamp prefill_start BEFORE the chunk-carrying call is issued
        (the wave path does the same), so the first chunk's time lands
        in the TTFT prefill span, not the queue wait.  A prefix-hit
        request starts its first chunk at the match point
        (``admit_pos``), not 0 — the skipped tokens belong to no span."""
        for r, _ in pwork:
            if r.pos == r.admit_pos:
                self.slo.prefill_started(r.rid)

    def _finish_chunks(self, pwork: list[tuple[Request, int]]):
        for r, n in pwork:
            r.pos += n
            self.slo.chunk_done(r.rid)
            if not r.prefilling:
                self.slo.prefill_done(r.rid)

    def _postprocess_decode(self, drows: list[Request], nxt: np.ndarray):
        for i, r in enumerate(drows):
            tok = int(nxt[i])
            if not r.generated:
                self.slo.first_token(r.rid)
            else:
                self.slo.token(r.rid)
            r.generated.append(tok)
            r.pos += 1
            if (len(r.generated) >= r.max_new_tokens
                    or r.pos >= self.ecfg.max_len - 1):
                self.slo.finish(r.rid)
                self.state.retire(r)
        self.state.decode_steps += 1
        if self.cfg.is_moe and self.sched.rebalance_due():
            self.rebalance()

    def _update_loads(self, stats):
        if not self.cfg.is_moe:
            return
        h = np.asarray(stats["expert_hist"])
        if h.shape[0] == self.cfg.num_experts:
            self.state.record_hist(h, self.ecfg.load_ewma)

    # ------------------------------------------------------------------
    def run(self, max_iters: int = 10_000):
        """Run until queue + active drain (or max_iters)."""
        it = 0
        while self.has_work and it < max_iters:
            self.step()
            it += 1
        return self.slo.summary()

    def finished_requests(self):
        return {rid: t for rid, t in self.slo.timings.items()
                if t.finished > 0}
