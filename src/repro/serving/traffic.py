"""Synthetic traffic for the serving engine: Poisson arrivals, mixed
prompt/output-length distributions, and open/closed-loop replay.

The paper's end-to-end decode-throughput-at-fixed-SLO results (Fig.
9–12) are measured under sustained multi-tenant load, not one batch of
hand-fed prompts.  This module provides the load side:

  * :func:`generate_trace` — a deterministic (seeded) request trace:
    exponential interarrival times at ``arrival_rate`` req/s and
    clipped-lognormal prompt/output lengths, optionally with a
    heavy-tail mixture (a fraction of "long" requests drawn at
    ``tail_scale``× the mean — the bimodality that makes batch
    composition, and therefore activated-expert counts, fluctuate).
    With ``prefix_groups > 0`` the trace becomes a shared-system-prompt
    / multi-turn stream (every prompt = prefix + fresh suffix;
    ``prefix_fraction`` sweeps how much of the stream is *shared*
    without changing total prompt work — the prefix-cache benchmark's
    controlled variable; ``turns_max > 1`` adds session chains whose
    prompts extend earlier prompts).
  * :func:`replay_open_loop` — arrivals happen at trace times on a
    virtual clock regardless of engine progress (rate-controlled load;
    queues grow when the engine falls behind — this is the regime where
    SLO percentiles mean something).  The virtual clock advances by
    ``step_time`` per engine iteration so CPU-sized runs are
    deterministic; ``step_time=None`` uses wall time.
  * :func:`replay_closed_loop` — a fixed number of outstanding clients;
    each completion immediately submits the next request (throughput-
    probing load, the classic saturation measurement).

Both replays drive :meth:`ServingEngine.step` directly, so admission,
wave prefill, bucketing, and paging are exercised exactly as in
:meth:`ServingEngine.run`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticRequest:
    arrival: float              # seconds since trace start
    prompt: np.ndarray          # [n] int32
    max_new_tokens: int


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    num_requests: int = 64
    arrival_rate: float = 50.0      # Poisson rate, requests / second
    prompt_len_mean: float = 12.0
    prompt_len_sigma: float = 0.6   # lognormal shape
    prompt_len_min: int = 2
    prompt_len_max: int = 48
    output_len_mean: float = 16.0
    output_len_sigma: float = 0.5
    output_len_min: int = 1
    output_len_max: int = 64
    tail_fraction: float = 0.0      # share of heavy-tail requests
    tail_scale: float = 4.0         # their length multiplier
    vocab_size: int = 256
    seed: int = 0
    # --- shared-prefix / multi-turn workload (prefix_groups=0 = off:
    #     generation is bit-identical to pre-prefix configs) ---
    prefix_groups: int = 0          # distinct shared "system prompts"
    prefix_len_mean: float = 24.0
    prefix_len_sigma: float = 0.3
    prefix_len_min: int = 8
    prefix_len_max: int = 64
    prefix_fraction: float = 1.0    # share of requests drawing a SHARED
                                    # group prefix; the rest get a
                                    # private prefix of the SAME length
                                    # (total prompt work is invariant to
                                    # the fraction — only *sharing*
                                    # varies, which is what a prefix-
                                    # cache sweep must isolate)
    turns_max: int = 1              # >1: multi-turn sessions — a later
                                    # request's prompt extends an earlier
                                    # prompt with a fresh user turn
                                    # (prompt-prefix chains)
    turn_continue_p: float = 0.5    # P(a request continues an open
                                    # session) when turns_max > 1
    prompt_total_max: int = 0       # cap on a chained prompt's length
                                    # (0 = prefix_len_max + turns_max *
                                    # prompt_len_max); a session that
                                    # would exceed it starts fresh


def _lengths(rng, n, mean, sigma, lo, hi, tail_fraction, tail_scale):
    mu = np.log(max(mean, 1.0)) - 0.5 * sigma ** 2
    out = rng.lognormal(mu, sigma, size=n)
    if tail_fraction > 0:
        tail = rng.random(n) < tail_fraction
        out[tail] *= tail_scale
    return np.clip(np.round(out), lo, hi).astype(np.int64)


def spawn_traffic_configs(tcfg: TrafficConfig,
                          num_replicas: int) -> list[TrafficConfig]:
    """Per-replica traffic configs with *derived* independent RNG
    streams (``np.random.SeedSequence.spawn``).

    Naive per-replica seeding (``seed + i``) risks overlapping or
    correlated streams; spawning gives each replica a statistically
    independent child stream while staying fully reproducible from the
    one parent seed — N replicas under load never see accidentally
    identical prompts or arrival processes, and re-running the same
    parent seed reproduces every replica's trace bit-for-bit.
    """
    children = np.random.SeedSequence(tcfg.seed).spawn(num_replicas)
    return [dataclasses.replace(tcfg, seed=int(c.generate_state(1)[0]))
            for c in children]


def generate_trace(tcfg: TrafficConfig) -> list[SyntheticRequest]:
    rng = np.random.default_rng(tcfg.seed)
    n = tcfg.num_requests
    arrivals = np.cumsum(rng.exponential(1.0 / tcfg.arrival_rate, size=n))
    p_lens = _lengths(rng, n, tcfg.prompt_len_mean, tcfg.prompt_len_sigma,
                      tcfg.prompt_len_min, tcfg.prompt_len_max,
                      tcfg.tail_fraction, tcfg.tail_scale)
    o_lens = _lengths(rng, n, tcfg.output_len_mean, tcfg.output_len_sigma,
                      tcfg.output_len_min, tcfg.output_len_max,
                      tcfg.tail_fraction, tcfg.tail_scale)
    if tcfg.prefix_groups > 0:
        return _shared_prefix_trace(tcfg, rng, arrivals, p_lens, o_lens)
    return [
        SyntheticRequest(
            arrival=float(arrivals[i]),
            prompt=rng.integers(0, tcfg.vocab_size, int(p_lens[i]),
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=int(o_lens[i]))
        for i in range(n)
    ]


def _shared_prefix_trace(tcfg: TrafficConfig, rng, arrivals, p_lens,
                         o_lens) -> list[SyntheticRequest]:
    """Shared-system-prompt / multi-turn request stream.

    Every request is ``prefix + fresh user suffix``.  The prefix is one
    of ``prefix_groups`` shared system prompts with probability
    ``prefix_fraction``, else a *private* prefix of the same group's
    length — so sweeping ``prefix_fraction`` changes only how much of
    the stream is SHARED, never how many prompt tokens the engine must
    hold, which is exactly the controlled variable a prefix-cache
    benchmark needs.  The RNG consumption schedule is also independent
    of ``prefix_fraction`` (shared/private both draw the private
    tokens), so two sweeps differ in nothing but sharing.

    With ``turns_max > 1``, a request may instead continue an open
    session: its prompt is a previous request's full prompt plus a new
    user turn — the prompt-prefix chains a multi-turn chat produces,
    and the deepest reuse a radix prefix cache can exploit.
    """
    n = tcfg.num_requests
    g_lens = _lengths(rng, tcfg.prefix_groups, tcfg.prefix_len_mean,
                      tcfg.prefix_len_sigma, tcfg.prefix_len_min,
                      tcfg.prefix_len_max, 0.0, 1.0)
    g_toks = [rng.integers(0, tcfg.vocab_size, int(gl), dtype=np.int64)
              .astype(np.int32) for gl in g_lens]
    total_cap = tcfg.prompt_total_max or (
        tcfg.prefix_len_max + tcfg.turns_max * tcfg.prompt_len_max)
    # draw the whole decision/token stream up front so consumption
    # never depends on the branch taken
    shared = rng.random(n) < tcfg.prefix_fraction
    groups = rng.integers(0, tcfg.prefix_groups, size=n)
    cont = rng.random(n) < tcfg.turn_continue_p
    priv = [rng.integers(0, tcfg.vocab_size, int(g_lens[groups[i]]),
                         dtype=np.int64).astype(np.int32)
            for i in range(n)]
    sess_pick = rng.integers(0, 1 << 30, size=n)
    sessions: list[tuple[np.ndarray, int]] = []   # (prompt, turns)
    out = []
    for i in range(n):
        suffix = rng.integers(0, tcfg.vocab_size, int(p_lens[i]),
                              dtype=np.int64).astype(np.int32)
        prompt = None
        if tcfg.turns_max > 1 and sessions and cont[i]:
            j = int(sess_pick[i] % len(sessions))
            prev, turns = sessions[j]
            if (turns < tcfg.turns_max
                    and len(prev) + len(suffix) <= total_cap):
                prompt = np.concatenate([prev, suffix])
                sessions[j] = (prompt, turns + 1)
        if prompt is None:
            prefix = g_toks[groups[i]] if shared[i] else priv[i]
            prompt = np.concatenate([prefix, suffix])
            if tcfg.turns_max > 1:
                sessions.append((prompt, 1))
        out.append(SyntheticRequest(
            arrival=float(arrivals[i]), prompt=prompt,
            max_new_tokens=int(o_lens[i])))
    return out


def replay_open_loop(engine, trace: list[SyntheticRequest], *,
                     step_time: Optional[float] = 5e-3,
                     max_iters: int = 100_000) -> dict:
    """Open-loop (rate-controlled) replay: submit each request at its
    trace arrival time, stepping the engine in between.  ``step_time``
    is the virtual seconds one engine iteration represents (None = wall
    clock).  Returns the engine's SLO summary."""
    import time as _time
    i, it = 0, 0
    t0 = engine.slo.now()
    while (i < len(trace) or engine.has_work) and it < max_iters:
        t = it * step_time if step_time is not None \
            else engine.slo.now() - t0
        while i < len(trace) and trace[i].arrival <= t:
            engine.submit(trace[i].prompt, trace[i].max_new_tokens)
            i += 1
        if engine.has_work:
            engine.step()
            it += 1
        elif i < len(trace):
            # idle gap before the next arrival
            if step_time is not None:
                # jump the virtual clock (one iteration consumed)
                it = max(it + 1,
                         int(np.ceil(trace[i].arrival / step_time)))
            else:
                # wall clock: sleep instead of busy-spinning the
                # iteration budget away
                _time.sleep(min(max(trace[i].arrival - t, 0.0), 0.05))
    return engine.slo.summary()


def replay_closed_loop(engine, trace: list[SyntheticRequest], *,
                       concurrency: int = 8,
                       max_iters: int = 100_000) -> dict:
    """Closed-loop replay: keep ``concurrency`` requests outstanding
    (arrival times in the trace are ignored)."""
    i, it = 0, 0
    outstanding = 0
    done_before = 0
    while (i < len(trace) or engine.has_work) and it < max_iters:
        while i < len(trace) and outstanding < concurrency:
            engine.submit(trace[i].prompt, trace[i].max_new_tokens)
            outstanding += 1
            i += 1
        engine.step()
        finished = len(engine.completed)
        outstanding -= finished - done_before
        done_before = finished
        it += 1
    return engine.slo.summary()
