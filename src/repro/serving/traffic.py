"""Synthetic traffic for the serving engine: Poisson arrivals, mixed
prompt/output-length distributions, and open/closed-loop replay.

The paper's end-to-end decode-throughput-at-fixed-SLO results (Fig.
9–12) are measured under sustained multi-tenant load, not one batch of
hand-fed prompts.  This module provides the load side:

  * :func:`generate_trace` — a deterministic (seeded) request trace:
    exponential interarrival times at ``arrival_rate`` req/s and
    clipped-lognormal prompt/output lengths, optionally with a
    heavy-tail mixture (a fraction of "long" requests drawn at
    ``tail_scale``× the mean — the bimodality that makes batch
    composition, and therefore activated-expert counts, fluctuate).
  * :func:`replay_open_loop` — arrivals happen at trace times on a
    virtual clock regardless of engine progress (rate-controlled load;
    queues grow when the engine falls behind — this is the regime where
    SLO percentiles mean something).  The virtual clock advances by
    ``step_time`` per engine iteration so CPU-sized runs are
    deterministic; ``step_time=None`` uses wall time.
  * :func:`replay_closed_loop` — a fixed number of outstanding clients;
    each completion immediately submits the next request (throughput-
    probing load, the classic saturation measurement).

Both replays drive :meth:`ServingEngine.step` directly, so admission,
wave prefill, bucketing, and paging are exercised exactly as in
:meth:`ServingEngine.run`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticRequest:
    arrival: float              # seconds since trace start
    prompt: np.ndarray          # [n] int32
    max_new_tokens: int


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    num_requests: int = 64
    arrival_rate: float = 50.0      # Poisson rate, requests / second
    prompt_len_mean: float = 12.0
    prompt_len_sigma: float = 0.6   # lognormal shape
    prompt_len_min: int = 2
    prompt_len_max: int = 48
    output_len_mean: float = 16.0
    output_len_sigma: float = 0.5
    output_len_min: int = 1
    output_len_max: int = 64
    tail_fraction: float = 0.0      # share of heavy-tail requests
    tail_scale: float = 4.0         # their length multiplier
    vocab_size: int = 256
    seed: int = 0


def _lengths(rng, n, mean, sigma, lo, hi, tail_fraction, tail_scale):
    mu = np.log(max(mean, 1.0)) - 0.5 * sigma ** 2
    out = rng.lognormal(mu, sigma, size=n)
    if tail_fraction > 0:
        tail = rng.random(n) < tail_fraction
        out[tail] *= tail_scale
    return np.clip(np.round(out), lo, hi).astype(np.int64)


def spawn_traffic_configs(tcfg: TrafficConfig,
                          num_replicas: int) -> list[TrafficConfig]:
    """Per-replica traffic configs with *derived* independent RNG
    streams (``np.random.SeedSequence.spawn``).

    Naive per-replica seeding (``seed + i``) risks overlapping or
    correlated streams; spawning gives each replica a statistically
    independent child stream while staying fully reproducible from the
    one parent seed — N replicas under load never see accidentally
    identical prompts or arrival processes, and re-running the same
    parent seed reproduces every replica's trace bit-for-bit.
    """
    children = np.random.SeedSequence(tcfg.seed).spawn(num_replicas)
    return [dataclasses.replace(tcfg, seed=int(c.generate_state(1)[0]))
            for c in children]


def generate_trace(tcfg: TrafficConfig) -> list[SyntheticRequest]:
    rng = np.random.default_rng(tcfg.seed)
    n = tcfg.num_requests
    arrivals = np.cumsum(rng.exponential(1.0 / tcfg.arrival_rate, size=n))
    p_lens = _lengths(rng, n, tcfg.prompt_len_mean, tcfg.prompt_len_sigma,
                      tcfg.prompt_len_min, tcfg.prompt_len_max,
                      tcfg.tail_fraction, tcfg.tail_scale)
    o_lens = _lengths(rng, n, tcfg.output_len_mean, tcfg.output_len_sigma,
                      tcfg.output_len_min, tcfg.output_len_max,
                      tcfg.tail_fraction, tcfg.tail_scale)
    return [
        SyntheticRequest(
            arrival=float(arrivals[i]),
            prompt=rng.integers(0, tcfg.vocab_size, int(p_lens[i]),
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=int(o_lens[i]))
        for i in range(n)
    ]


def replay_open_loop(engine, trace: list[SyntheticRequest], *,
                     step_time: Optional[float] = 5e-3,
                     max_iters: int = 100_000) -> dict:
    """Open-loop (rate-controlled) replay: submit each request at its
    trace arrival time, stepping the engine in between.  ``step_time``
    is the virtual seconds one engine iteration represents (None = wall
    clock).  Returns the engine's SLO summary."""
    import time as _time
    i, it = 0, 0
    t0 = engine.slo.now()
    while (i < len(trace) or engine.has_work) and it < max_iters:
        t = it * step_time if step_time is not None \
            else engine.slo.now() - t0
        while i < len(trace) and trace[i].arrival <= t:
            engine.submit(trace[i].prompt, trace[i].max_new_tokens)
            i += 1
        if engine.has_work:
            engine.step()
            it += 1
        elif i < len(trace):
            # idle gap before the next arrival
            if step_time is not None:
                # jump the virtual clock (one iteration consumed)
                it = max(it + 1,
                         int(np.ceil(trace[i].arrival / step_time)))
            else:
                # wall clock: sleep instead of busy-spinning the
                # iteration budget away
                _time.sleep(min(max(trace[i].arrival - t, 0.0), 0.05))
    return engine.slo.summary()


def replay_closed_loop(engine, trace: list[SyntheticRequest], *,
                       concurrency: int = 8,
                       max_iters: int = 100_000) -> dict:
    """Closed-loop replay: keep ``concurrency`` requests outstanding
    (arrival times in the trace are ignored)."""
    i, it = 0, 0
    outstanding = 0
    done_before = 0
    while (i < len(trace) or engine.has_work) and it < max_iters:
        while i < len(trace) and outstanding < concurrency:
            engine.submit(trace[i].prompt, trace[i].max_new_tokens)
            outstanding += 1
            i += 1
        engine.step()
        finished = len(engine.completed)
        outstanding -= finished - done_before
        done_before = finished
        it += 1
    return engine.slo.summary()
