"""Radix prefix index over the paged KV pool: shared-prefix reuse.

Real serving traffic is prefix-heavy — system prompts, few-shot
preambles, and multi-turn sessions repeat the same leading tokens across
millions of requests.  Prefilling those tokens again and again is pure
waste, and (per the paper's memory-bound thesis) the cycles it burns are
*compute* cycles stolen from a decode phase that is already starved for
HBM bandwidth.  This module caches the KV pages a completed prefill
wrote and lets a new request start its prefill at the end of its longest
cached prefix: skipped tokens never enter chunk planning, the TTFT
prefill span, or the expert-load EWMA.

Design (pages are the unit of storage, tokens the unit of matching):

  * The index is a radix trie in which **every node owns exactly one
    physical page** of the pool.  A node's key is the page's token
    content — ``page_size`` tokens for interior/full nodes, fewer for a
    *partial tail* node (a cached prefix whose length is not
    page-aligned; always a leaf).
  * **Match** walks full-page-exact hops as far as possible (those
    pages are shared read-only into the new request's page table), then
    takes the best token-level partial match into one more node.  A
    partial match — or a full match of a partial tail — means the new
    request will write its own tokens into that page, so the page is a
    **copy-on-write source**: the scheduler allocates a fresh page and
    copies the device contents before the request's first chunk runs
    (``Executor.run_copy_pages``).  Positions below the match point in
    the copy are canonical prefix KV; positions at/above it are
    overwritten by the request's own prefill/decode before they can be
    attended (the causal mask admits only ``spos <= pos``).
  * **Insert** happens when a request *retires*: the prefilled prefix
    (``context_tokens()[:n_ctx]`` — always canonical: position ``p``
    holds token ``p``'s KV) is walked into the trie.  Pages whose token
    content already has a node are **deduplicated** (the retiring copy
    is simply released with the slot); only diverging pages are
    indexed.  KV content for a given (token sequence, position) is
    deterministic — independent of batch composition, chunk split, and
    physical page id (``row_valid`` keeps MoE routing padding-invariant
    and attention reads are page-table gathers) — which is what makes
    both dedup and reuse bitwise safe (pinned by
    tests/test_prefix_cache.py).
  * **Evict** is leaf-first LRU: a node is evictable when it has no
    children and no page-table entry maps its page (the manager's
    refcount — shared ancestors of an in-flight request are pinned by
    construction because a match maps every ancestor page).  Evicting a
    leaf may expose its parent.  ``reclaim(n)`` frees up to ``n`` pages
    and is driven by the page-aware admission policy and by
    ``Scheduler.reserve`` *before* any running request is preempted —
    cache is always cheaper to drop than work is to recompute.

Restrictions: attention layers only.  Mamba/SSM state is O(1) per
sequence and not paged, so a mid-sequence snapshot would have to be
captured per page boundary to resume from an arbitrary match point; the
engine auto-disables the prefix cache for mamba-bearing architectures
(see ``ServingEngine.prefix_enabled``).  Sliding-window layers work
unchanged: paged SWA stores the full sequence and masks the window at
read time, so shared pages hold exactly what a cold prefill would have
written.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.serving.kv import PagedKVManager


@dataclasses.dataclass
class PrefixMatch:
    """Result of matching a token sequence against the index.

    ``m``        — matched token count (prefill may start at position m).
    ``pages``    — physical pages fully covered by the match, in logical
                   order; shared read-only into the new slot's table.
    ``cow_src``  — physical page a token-level partial match landed in
                   (None when the match ends exactly on a page
                   boundary); the request's boundary page must be
                   *copied* from it before first use.
    ``nodes``    — the matched trie path (full nodes + the CoW node),
                   for the LRU touch at commit time.
    """
    m: int
    pages: list
    cow_src: Optional[int]
    nodes: list

    @property
    def hit(self) -> bool:
        return self.m > 0


class _Node:
    __slots__ = ("tokens", "page", "children", "parent", "last_access",
                 "nid")

    def __init__(self, tokens, page, parent, nid, tick):
        self.tokens = tokens        # tuple[int], len <= page_size
        self.page = page            # physical page id
        self.children = {}          # token-tuple -> _Node
        self.parent = parent
        self.last_access = tick
        self.nid = nid


def _common(a: tuple, b) -> int:
    n = min(len(a), len(b))
    k = 0
    while k < n and a[k] == int(b[k]):
        k += 1
    return k


class RadixPrefixIndex:
    """Token-content radix trie over physical KV pages (host side)."""

    def __init__(self, kvman: PagedKVManager, page_size: int):
        assert page_size == kvman.page_size
        self.kvman = kvman
        self.ps = page_size
        self._root = _Node((), -1, None, -1, 0)
        self._tick = 0
        self._next_id = 0
        # observables
        self.hits = 0
        self.misses = 0
        self.inserted_pages = 0
        self.deduped_pages = 0
        self.evicted_pages = 0

    # ------------------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        def count(n):
            return sum(1 + count(c) for c in n.children.values())
        return count(self._root)

    def cached_pages(self) -> int:
        return int(self.kvman.indexed.sum())

    def _tok(self):
        self._tick += 1
        return self._tick

    # ------------------------------------------------------------------
    # match
    # ------------------------------------------------------------------
    def match(self, tokens) -> PrefixMatch:
        """Longest cached prefix of ``tokens`` (pure: no LRU update —
        commit a taken match with :meth:`touch`).  Deterministic: exact
        full-page hops first, then the child sharing the most leading
        tokens (ties to the oldest node)."""
        toks = np.asarray(tokens)
        node, i = self._root, 0
        pages: list[int] = []
        path: list[_Node] = []
        n = len(toks)
        while True:
            rem = n - i
            if rem >= self.ps:
                child = node.children.get(
                    tuple(int(t) for t in toks[i:i + self.ps]))
                if child is not None and len(child.tokens) == self.ps:
                    pages.append(child.page)
                    path.append(child)
                    node = child
                    i += self.ps
                    continue
            best, bk = None, 0
            for key, ch in node.children.items():
                k = _common(key, toks[i:i + len(key)])
                if k > bk or (k == bk and k > 0 and ch.nid < best.nid):
                    best, bk = ch, k
            break
        if bk > 0:
            path.append(best)
            return PrefixMatch(i + bk, pages, best.page, path)
        return PrefixMatch(i, pages, None, path)

    def touch(self, match: PrefixMatch):
        """Bump the LRU clock on a taken match's path."""
        for nd in match.nodes:
            nd.last_access = self._tok()

    # ------------------------------------------------------------------
    # insert
    # ------------------------------------------------------------------
    def insert(self, tokens, phys_pages) -> int:
        """Index the prefilled prefix ``tokens`` backed by ``phys_pages``
        (the owning slot's table entries, logical order — must still be
        mapped: call before the slot is released).  Pages whose content
        is already cached are deduplicated; returns how many pages were
        newly indexed."""
        toks = np.asarray(tokens)
        n = len(toks)
        assert len(phys_pages) == -(-n // self.ps)
        node, i, pi, added = self._root, 0, 0, 0
        while i < n:
            c = min(self.ps, n - i)
            key = tuple(int(t) for t in toks[i:i + c])
            child = node.children.get(key)
            if child is not None:
                # identical page content already cached: dedupe (the
                # retiring copy is released with its slot)
                child.last_access = self._tok()
                self.deduped_pages += 1
                node = child
                i += c
                pi += 1
                continue
            if c < self.ps and any(len(k2) > c and k2[:c] == key
                                   for k2 in node.children):
                # the new partial tail is a strict prefix of an
                # existing (longer) page — that page already serves
                # every match this one could (the CoW copy takes only
                # matched offsets), so indexing it would just pin a
                # redundant page
                self.deduped_pages += 1
                break
            # conversely, a now-redundant existing partial tail (its
            # tokens are a strict prefix of the new page) is dropped
            # when free — the longer node subsumes it
            for k2 in list(node.children):
                ch = node.children[k2]
                if (len(ch.tokens) < c and key[:len(ch.tokens)] == k2
                        and not ch.children and self._evictable(ch)):
                    self._evict(ch)
            page = int(phys_pages[pi])
            self.kvman.index_page(page)
            new = _Node(key, page, node, self._next_id, self._tok())
            self._next_id += 1
            node.children[key] = new
            self.inserted_pages += 1
            added += 1
            node = new
            i += c
            pi += 1
        return added

    # ------------------------------------------------------------------
    # eviction (leaf-first LRU)
    # ------------------------------------------------------------------
    def _evictable(self, nd: _Node) -> bool:
        return (not nd.children and self.kvman.refcount[nd.page] == 0
                and self.kvman._pins[nd.page] == 0)

    def _evict(self, nd: _Node):
        del nd.parent.children[nd.tokens]
        self.kvman.unindex_page(nd.page)
        self.evicted_pages += 1

    def _evictable_leaves(self) -> list:
        out = []

        def walk(n):
            for c in n.children.values():
                walk(c)
                if self._evictable(c):
                    out.append(c)
        walk(self._root)
        return out

    def reclaim(self, n_pages: int) -> int:
        """Evict least-recently-used evictable leaves until ``n_pages``
        pages went back to the free list (or nothing is left to evict).
        Returns the number freed.  One trie walk total: evicting a leaf
        may expose its parent, which joins the heap — admission and
        ``Scheduler.reserve`` call this under pool pressure, so the
        cost must not scale with (nodes x pages)."""
        freed = 0
        heap = [((nd.last_access, nd.nid), nd)
                for nd in self._evictable_leaves()]
        heapq.heapify(heap)
        while freed < n_pages and heap:
            _, nd = heapq.heappop(heap)
            parent = nd.parent
            self._evict(nd)
            freed += 1
            if parent is not self._root and self._evictable(parent):
                heapq.heappush(
                    heap, ((parent.last_access, parent.nid), parent))
        return freed

    def clear(self) -> int:
        """Drop every evictable node (cache flush); returns pages freed."""
        return self.reclaim(self.kvman.num_pages)

    # ------------------------------------------------------------------
    # invariants (tests + hypothesis fuzz)
    # ------------------------------------------------------------------
    def check_consistent(self):
        """Index invariants: every node owns a distinct page, the set of
        node pages is exactly the manager's ``indexed`` set, interior
        nodes are full pages, and partial nodes are leaves."""
        pages = []

        def walk(nd):
            for c in nd.children.values():
                assert len(c.tokens) <= self.ps
                if len(c.tokens) < self.ps:
                    assert not c.children, "partial node with children"
                pages.append(c.page)
                walk(c)
        walk(self._root)
        assert len(pages) == len(set(pages)), \
            "two index nodes own the same page"
        want = set(int(p) for p in np.where(self.kvman.indexed)[0])
        assert set(pages) == want, \
            "index nodes disagree with kvman.indexed"
