"""Execution layer: jitted step functions, input packing, and the
physical expert-weight substrate (placement, routing tables, reshuffle).

The executor owns everything that touches jax: the per-shape-signature
jit cache, the decode/prefill/chunk/mixed step builders, the numpy->jnp
input packers, the KV cache pytree, and the EPLB placement + routing
tables + logical master weights the rebalance loop reshuffles.  It
makes *no* scheduling decisions — the engine façade hands it rows the
scheduler already picked.

Step builders close over ``(cfg, dist, ecfg)`` only; params / cache /
routing enter as call arguments.  Engines built from identical configs
can therefore share one ``fn_cache`` (the cluster layer does this so N
replicas compile each signature once) — sharing across *different*
configs is invalid and the caller's responsibility to avoid.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import build_placement
from repro.models import lm as LM
from repro.serving.expert_pool import build_expert_pool
from repro.serving.kv import pages_for
from repro.serving.scheduler import _pow2
from repro.serving.state import Request
from repro.sharding.policy import Dist

# EngineConfig.kv_dtype -> pool dtype.  fp8 pools are dequantized to
# bf16 inside the paged read paths (gather reference and Pallas kernels
# both branch on itemsize == 1); writes quantize on the scatter's
# astype.  Parity vs an fp32 pool is tolerance-pinned in
# tests/test_prefix_cache.py.
KV_DTYPES = {
    "bf16": jnp.bfloat16,
    "fp32": jnp.float32,
    "fp8": jnp.float8_e4m3fn,
}


class Executor:
    def __init__(self, cfg: ModelConfig, dist: Dist, ecfg, params, slo,
                 routing_table_width: int = 0,
                 fn_cache: Optional[dict] = None):
        self.cfg = cfg
        self.dist = dist
        self.ecfg = ecfg
        self.params = params
        self.slo = slo
        self._table_width = routing_table_width

        if cfg.is_moe:
            self.placement = build_placement(
                cfg.num_experts, dist.ep_size, dist.slots_per_device,
                loads=np.ones(cfg.num_experts))
            if not self._table_width:
                self._table_width = min(
                    dist.num_slots - cfg.num_experts + 1, dist.ep_size * 2)
                self._table_width = max(self._table_width,
                                        self.placement.max_replicas)
            self.routing = LM.build_lm_routing(cfg, self.placement,
                                               self._table_width)
            # logical master weights (for rebalance reshuffling)
            self._logical = self._extract_logical(params)
        else:
            self.placement, self.routing = None, {}

        # paged expert-weight pool (host <-> HBM, activation-aware
        # prefetch).  Host-side working-set bookkeeping: the step
        # functions always compute on the true weights (a fetch always
        # completes before use, so residency never changes the math);
        # the pool's fetch bytes feed virtual-time cost models and the
        # SLO's stall attribution.
        self.expert_pool = (
            build_expert_pool(cfg, ecfg, dist.num_slots)
            if cfg.is_moe and getattr(ecfg, "expert_pool", False)
            else None)

        kv_dtype = KV_DTYPES[getattr(ecfg, "kv_dtype", "bf16")]
        if ecfg.kv_layout == "paged":
            pmax = pages_for(ecfg.max_len, ecfg.page_size)
            num_pages = ecfg.num_pages or ecfg.max_batch * pmax
            self.cache = LM.init_paged_cache(
                cfg, dist, num_pages, ecfg.page_size, ecfg.max_batch,
                dtype=kv_dtype)
        else:
            self.cache = LM.init_cache(cfg, dist, ecfg.max_batch,
                                       ecfg.max_len)
        if fn_cache is None:
            fn_cache = {"decode": {}, "prefill": {}, "chunk": {},
                        "mixed": {}, "copy": {}}
        self._fns: dict[str, dict] = fn_cache

    # ------------------------------------------------------------------
    # weight reshuffling (EPLB rebalance)
    # ------------------------------------------------------------------
    def _extract_logical(self, params):
        """Logical expert master: replica 0 of each expert."""
        first_slot = np.array([
            self.placement.expert_slots[e, 0]
            for e in range(self.cfg.num_experts)])
        out = {}

        def grab(tree, path=()):
            for k, v in tree.items():
                if isinstance(v, dict):
                    grab(v, path + (k,))
                elif k in ("w_up", "w_down") and v.ndim >= 4:
                    out[path + (k,)] = np.asarray(v)[:, first_slot]
        grab(params["blocks"])
        return out

    def rebalance(self, loads: np.ndarray,
                  placement=None):
        """Install a new EPLB placement (recomputed from ``loads``
        unless the cluster hands down a shared one) and reshuffle the
        physical expert weights to it.  Replica choice moves compute,
        not math: every replica of an expert holds identical weights,
        so a reshuffle is bitwise invisible to in-flight requests."""
        if not self.cfg.is_moe:
            return
        if placement is None:
            placement = build_placement(
                self.cfg.num_experts, self.dist.ep_size,
                self.dist.slots_per_device, loads=loads)
        if self.expert_pool is not None:
            # the reshuffle rewrites any slot whose expert assignment
            # changed — its cached pages (every layer) are stale
            changed = np.nonzero(
                np.asarray(self.placement.replica_expert)
                != np.asarray(placement.replica_expert))[0]
            self.expert_pool.invalidate_slots(changed)
        self.placement = placement
        self.routing = LM.build_lm_routing(self.cfg, placement,
                                           self._table_width)
        idx = placement.replica_expert

        def put(tree, path=()):
            for k, v in list(tree.items()):
                if isinstance(v, dict):
                    put(v, path + (k,))
                elif k in ("w_up", "w_down") and v.ndim >= 4:
                    tree[k] = jnp.asarray(self._logical[path + (k,)][:, idx])
        put(self.params["blocks"])

    # ------------------------------------------------------------------
    # step functions (compiled once per shape signature)
    # ------------------------------------------------------------------
    def _get_fn(self, kind: str, key, builder):
        # setdefault: externally-supplied fn_caches predating a kind
        # (e.g. "copy") still work
        fns = self._fns.setdefault(kind, {})
        if key not in fns:
            fns[key] = builder()
            self.slo.compiled(kind, key)
        return fns[key]

    def compiled_buckets(self, kind: str):
        """Shape keys already built for ``kind`` (the scheduler's
        bucket-grace policy reads the decode set)."""
        return self._fns.setdefault(kind, {}).keys()

    def decode_fn(self, bucket: int):
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            paged = ecfg.kv_layout == "paged"

            @jax.jit
            def step(params, tokens, pos, slot_idx, page_table, cache,
                     routing):
                logits, new_cache, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, pos=pos, cache=cache,
                    routing=routing, mode="decode", algo=ecfg.decode_algo,
                    moe_impl=ecfg.moe_impl,
                    use_pallas_route=ecfg.use_pallas_route,
                    slot_idx=slot_idx,
                    page_table=page_table if paged else None,
                    row_valid=slot_idx < ecfg.max_batch,
                    use_flash_kernel=ecfg.use_flash_kernel)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, new_cache, stats
            return step
        return self._get_fn("decode", bucket, build)

    def prefill_fn(self, batch: int, length: int):
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            paged = ecfg.kv_layout == "paged"

            @jax.jit
            def step(params, tokens, lengths, slot_idx, page_table, cache,
                     routing):
                wave = LM.init_wave_cache(cfg, dist, batch, length)
                _, filled, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, cache=wave,
                    routing=routing, mode="prefill",
                    algo=ecfg.prefill_algo, moe_impl=ecfg.moe_impl,
                    use_pallas_route=ecfg.use_pallas_route,
                    chunk=ecfg.prefill_chunk,
                    row_valid=jnp.arange(length)[None, :]
                    < lengths[:, None])
                new_cache = LM.merge_wave_cache(
                    cfg, cache, filled, slot_idx, lengths,
                    page_table=page_table if paged else None,
                    page_size=ecfg.page_size)
                return new_cache, stats
            return step
        return self._get_fn("prefill", (batch, length), build)

    def chunk_fn(self, batch: int):
        """One resumable prefill chunk for ``batch`` rows: [B, C] tokens
        written straight into the paged serving cache (no wave scratch,
        no O(max_len) buffer — C = prefill_chunk is the only length)."""
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            c = ecfg.prefill_chunk

            @jax.jit
            def step(params, tokens, start, n_tok, slot_idx, page_table,
                     cache, routing):
                _, new_cache, stats = LM.apply_lm(
                    cfg, dist, params, tokens=tokens, pos=start,
                    cache=cache, routing=routing, mode="chunk_prefill",
                    algo=ecfg.prefill_algo, moe_impl=ecfg.moe_impl,
                    use_pallas_route=ecfg.use_pallas_route,
                    slot_idx=slot_idx, page_table=page_table,
                    row_valid=jnp.arange(c)[None, :] < n_tok[:, None])
                return new_cache, stats
            return step
        return self._get_fn("chunk", batch, build)

    def mixed_fn(self, bp: int, bd: int):
        """Fused mixed step: ``bp`` prefill-chunk rows and ``bd`` decode
        rows in ONE jitted call — the chunk sub-graph writes its pages,
        then the decode sub-graph runs against the updated cache, exactly
        the pure-phase chunk-then-decode sequence (bitwise: the
        equivalence test), but decode no longer waits for a dispatch."""
        def build():
            cfg, dist, ecfg = self.cfg, self.dist, self.ecfg
            c = ecfg.prefill_chunk

            @jax.jit
            def step(params, p_tokens, p_start, p_ntok, p_slot, p_pt,
                     d_tokens, d_pos, d_slot, d_pt, cache, routing):
                _, cache1, st_p = LM.apply_lm(
                    cfg, dist, params, tokens=p_tokens, pos=p_start,
                    cache=cache, routing=routing, mode="chunk_prefill",
                    algo=ecfg.prefill_algo, moe_impl=ecfg.moe_impl,
                    use_pallas_route=ecfg.use_pallas_route,
                    slot_idx=p_slot, page_table=p_pt,
                    row_valid=jnp.arange(c)[None, :] < p_ntok[:, None])
                logits, cache2, st_d = LM.apply_lm(
                    cfg, dist, params, tokens=d_tokens, pos=d_pos,
                    cache=cache1, routing=routing, mode="decode",
                    algo=ecfg.decode_algo, moe_impl=ecfg.moe_impl,
                    use_pallas_route=ecfg.use_pallas_route,
                    slot_idx=d_slot, page_table=d_pt,
                    row_valid=d_slot < ecfg.max_batch,
                    use_flash_kernel=ecfg.use_flash_kernel)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, cache2, st_p, st_d
            return step
        return self._get_fn("mixed", (bp, bd), build)

    def copy_fn(self):
        """Copy-on-write page copy: duplicate one physical page's K/V
        contents (every attention layer's pool) into a fresh page, so a
        prefix-hit request can write its own suffix into the boundary
        page without corrupting the shared original.  Only the first
        ``keep`` token offsets (the matched prefix tokens living in the
        boundary page) are copied; the rest of the destination page is
        zeroed — exactly the state a cold prefill would find, which is
        what makes a hit request's pages BITWISE equal to the cold
        run's (and keeps stale source bytes from ever entering the
        copy).  One jitted signature total — src/dst/keep are data, and
        per-slot (mamba) cache entries pass through untouched (the
        prefix cache is disabled for mamba-bearing archs; their state
        is not paged)."""
        def build():
            @jax.jit
            def fn(cache, src, dst, keep):
                out = {}
                for li, pool in cache.items():
                    if "k" not in pool:
                        out[li] = pool
                        continue
                    ps = pool["k"].shape[2]
                    mask = (jnp.arange(ps) < keep)[None, :, None, None]
                    out[li] = {kk: pool[kk].at[:, dst].set(
                        jnp.where(mask, pool[kk][:, src],
                                  jnp.zeros((), pool[kk].dtype)))
                        for kk in ("k", "v")}
                return out
            return fn
        return self._get_fn("copy", 0, build)

    def run_copy_pages(self, src: int, dst: int, keep: int):
        """Device copy of physical page ``src`` -> ``dst``: the first
        ``keep`` token offsets, rest zeroed (CoW boundary page)."""
        fn = self.copy_fn()
        self.cache = fn(self.cache, jnp.int32(src), jnp.int32(dst),
                        jnp.int32(keep))

    # ------------------------------------------------------------------
    # input packing (numpy host state -> padded jnp step inputs)
    # ------------------------------------------------------------------
    def chunk_inputs(self, pwork: list[tuple[Request, int]], b: int,
                     kvman):
        ecfg = self.ecfg
        c = ecfg.prefill_chunk
        pmax = pages_for(ecfg.max_len, ecfg.page_size)
        toks = np.zeros((b, c), np.int32)
        start = np.zeros((b,), np.int32)
        n_tok = np.zeros((b,), np.int32)
        slot_idx = np.full((b,), ecfg.max_batch, np.int32)
        pt = np.full((b, pmax), -1, np.int32)
        for i, (r, n) in enumerate(pwork):
            ctx = r.context_tokens()
            toks[i, :n] = ctx[r.pos:r.pos + n]
            start[i] = r.pos
            n_tok[i] = n
            slot_idx[i] = r.slot
        pt[:len(pwork)] = kvman.rows([r.slot for r, _ in pwork])
        return (jnp.asarray(toks), jnp.asarray(start), jnp.asarray(n_tok),
                jnp.asarray(slot_idx), jnp.asarray(pt))

    def decode_inputs(self, drows: list[Request], b: int, kvman):
        ecfg = self.ecfg
        pmax = pages_for(ecfg.max_len, ecfg.page_size)
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        slot_idx = np.full((b,), ecfg.max_batch, np.int32)
        pt = np.full((b, pmax), -1, np.int32)
        for i, r in enumerate(drows):
            tokens[i, 0] = (r.generated[-1] if r.generated
                            else int(r.context_tokens()[-1]))
            # a row finishing its prefill THIS iteration decodes at
            # n_ctx (its r.pos advances when the chunk completes); an
            # already-decoding row is simply at r.pos.  (n_ctx +
            # len(generated) would be wrong after a mid-decode
            # preemption: the re-prefilled n_ctx already contains the
            # generated tokens.)
            pos[i] = r.n_ctx if r.prefilling else r.pos
            slot_idx[i] = r.slot
        if kvman is not None:
            pt[:len(drows)] = kvman.rows([r.slot for r in drows])
        return (jnp.asarray(tokens), jnp.asarray(pos),
                jnp.asarray(slot_idx), jnp.asarray(pt))

    # ------------------------------------------------------------------
    # expert-pool accounting (host bookkeeping per executed call)
    # ------------------------------------------------------------------
    def _pool_step(self, stats, kind: str):
        """Replay one call's per-layer activated slots (the router's
        ``slot_hist``) through the expert pool: acquire/release each
        MoE layer's pages in sequence, exactly the order the forward
        pass touches them.  Returns (stats + pool counters, the
        accessed page ids in layer order — the next step's prefetch
        plan)."""
        pool = self.expert_pool
        if pool is None:
            return stats, []
        sh = np.asarray(stats["slot_hist"])
        assert sh.shape == (pool.n_layers, pool.n_slots), sh.shape
        hits = misses = planned = miss_b = 0
        accessed: list[int] = []
        for li in range(sh.shape[0]):
            pids = [pool.page_id(li, int(s))
                    for s in np.nonzero(sh[li] > 0)[0]]
            res = pool.acquire(pids, kind=kind)
            pool.release(pids)
            hits += res["hits"]
            misses += res["misses"]
            planned += res["planned_hits"]
            miss_b += res["miss_bytes"]
            accessed.extend(pids)
        stats = dict(stats)
        stats.update(pool_hits=float(hits), pool_misses=float(misses),
                     pool_planned_hits=float(planned),
                     pool_miss_bytes=float(miss_b))
        return stats, accessed

    def _pool_plan(self, stats, pids, kind: str):
        """Install step t's accessed pages as step t+1's prefetch plan
        and charge the overlapped fetch bytes to this call's stats."""
        pool = self.expert_pool
        if pool is None:
            return stats
        pref = pool.plan_prefetch(pids, kind=kind)
        stats["pool_prefetch_bytes"] = float(pref)
        return stats

    # ------------------------------------------------------------------
    # step execution (timed; SLO attribution stays in the façade)
    # ------------------------------------------------------------------
    def run_decode(self, drows: list[Request], bucket: int, kvman):
        tokens, pos, slot_idx, pt = self.decode_inputs(drows, bucket,
                                                       kvman)
        fn = self.decode_fn(bucket)
        t0 = time.perf_counter()
        nxt, self.cache, stats = fn(
            self.params, tokens, pos, slot_idx, pt, self.cache,
            self.routing)
        nxt = np.asarray(nxt)
        wall = time.perf_counter() - t0
        stats, pids = self._pool_step(stats, "decode")
        stats = self._pool_plan(stats, pids, "decode")
        return nxt, stats, wall

    def run_chunk(self, pwork: list[tuple[Request, int]], bp: int, kvman):
        toks, start, n_tok, slot_idx, pt = self.chunk_inputs(pwork, bp,
                                                             kvman)
        fn = self.chunk_fn(bp)
        t0 = time.perf_counter()
        self.cache, stats = fn(self.params, toks, start, n_tok,
                               slot_idx, pt, self.cache, self.routing)
        jax.block_until_ready(stats)
        wall = time.perf_counter() - t0
        stats, pids = self._pool_step(stats, "chunk")
        stats = self._pool_plan(stats, pids, "chunk")
        return stats, wall

    def run_mixed(self, pwork: list[tuple[Request, int]],
                  drows: list[Request], bp: int, bd: int, kvman):
        p_toks, p_start, p_ntok, p_slot, p_pt = \
            self.chunk_inputs(pwork, bp, kvman)
        # decode inputs are computed AFTER the chunk advances each
        # finishing row, so build them from the planned post-chunk state
        d_toks, d_pos, d_slot, d_pt = self.decode_inputs(drows, bd, kvman)
        fn = self.mixed_fn(bp, bd)
        t0 = time.perf_counter()
        nxt, self.cache, st_p, st_d = fn(
            self.params, p_toks, p_start, p_ntok, p_slot, p_pt,
            d_toks, d_pos, d_slot, d_pt, self.cache, self.routing)
        nxt = np.asarray(nxt)
        wall = time.perf_counter() - t0
        st_p, pids_p = self._pool_step(st_p, "chunk")
        st_d, pids_d = self._pool_step(st_d, "decode")
        # plan: decode pages first (they gate the next decode step),
        # then the chunk's, deduplicated preserving order
        plan = list(dict.fromkeys(pids_d + pids_p))
        st_d = self._pool_plan(st_d, plan, "decode")
        return nxt, st_p, st_d, wall

    def run_wave(self, group: list[Request], lens: list[int], kvman):
        ecfg = self.ecfg
        ctxs = [r.context_tokens() for r in group]
        b = _pow2(len(group))
        l_pad = min(max(_pow2(max(lens)), 8), ecfg.max_len)
        pmax = pages_for(ecfg.max_len, ecfg.page_size)
        toks = np.zeros((b, l_pad), np.int32)
        lengths = np.zeros((b,), np.int32)
        slot_idx = np.full((b,), ecfg.max_batch, np.int32)  # OOB = pad row
        pt = np.full((b, pmax), -1, np.int32)
        for i, r in enumerate(group):
            toks[i, :lens[i]] = ctxs[i][:lens[i]]
            lengths[i] = lens[i]
            slot_idx[i] = r.slot
        if kvman is not None:
            pt[:len(group)] = kvman.rows([r.slot for r in group])
        fn = self.prefill_fn(b, l_pad)
        t0 = time.perf_counter()
        self.cache, stats = fn(
            self.params, jnp.asarray(toks), jnp.asarray(lengths),
            jnp.asarray(slot_idx), jnp.asarray(pt), self.cache,
            self.routing)
        jax.block_until_ready(stats)
        wall = time.perf_counter() - t0
        stats, pids = self._pool_step(stats, "prefill")
        stats = self._pool_plan(stats, pids, "prefill")
        return stats, wall
