"""Scheduling policy: admission, chunk planning, preemption, bucketing,
and the rebalance window.

Pure host-side decisions over :class:`repro.serving.state.EngineState`
— no jax, no device work.  The engine façade asks the scheduler *what*
to run each iteration and hands the chosen rows to the executor; this
separation is what lets the cluster layer drive many engines with
different placement policies without touching the jit path.
"""
from __future__ import annotations

from collections import deque

from repro.serving.kv import pages_for
from repro.serving.state import EngineState, Request


def _pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())


class Scheduler:
    def __init__(self, ecfg, state: EngineState, slo, chunked: bool):
        self.ecfg = ecfg
        self.state = state
        self.slo = slo
        self.chunked = chunked
        self._bucket_demand: dict[int, int] = {}
        self._rebalance_pending = False
        self._rebalance_pending_since = 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def admit(self) -> list[Request]:
        """Admit waiting requests into free slots.

        Chunked prefill only needs pages for a request's FIRST chunk, so
        a page-blocked request no longer blocks the whole queue: the
        scan continues past it and admits any later request that fits
        (slots stay strictly FCFS — running out of slots stops the
        scan).  ``prefill_mode="wave"`` needs every context page up
        front and keeps the seed's strict head-of-line gate.
        """
        st, ecfg = self.state, self.ecfg
        admitted: list[Request] = []
        if not st.queue or not st.free_slots:
            return admitted
        remaining: deque[Request] = deque()    # page-blocked, scanned past
        while st.queue and st.free_slots:
            r = st.queue.popleft()
            n_ctx = min(len(r.context_tokens()), ecfg.max_len - 1)
            first = min(n_ctx, ecfg.prefill_chunk) if self.chunked \
                else n_ctx
            if st.kvman is not None and \
                    pages_for(first, ecfg.page_size) > st.kvman.num_free:
                remaining.append(r)
                if not self.chunked:
                    break               # strict FCFS: wait for pages
                continue
            st.activate(r, n_ctx, first)
            admitted.append(r)
            self.slo.admitted(r.rid)
        # splice the untouched tail back (skipped requests were earlier
        # in the queue, so relative order is preserved); O(1) when the
        # scan never started
        remaining.extend(st.queue)
        st.queue = remaining
        return admitted

    # ------------------------------------------------------------------
    # preemption / page reservation
    # ------------------------------------------------------------------
    def preempt_one(self, protect_rid: int) -> bool:
        """Evict the youngest active request (≠ protect_rid): free its
        pages + slot and requeue it for recompute-on-readmission.  A
        victim caught *between prefill chunks* releases every page it
        has written so far; readmission recomputes bitwise to the state
        an unpreempted run would have reached (the prefill-phase
        regression test).  A victim caught mid-DECODE replays
        prompt+generated as context, which collapses the re-fed
        boundary token the continued run kept at position n_ctx — its
        continuation is correct-by-recompute but not bitwise the
        unpreempted one (seed semantics, unchanged)."""
        st = self.state
        victims = [r for r in st.active.values() if r.rid != protect_rid]
        if not victims:
            return False
        v = max(victims, key=lambda r: r.rid)
        st.evict(v)
        self.slo.preemptions += 1
        return True

    def reserve(self, targets: list[tuple[Request, int]]):
        """Grow each target row's page table to cover ``want`` tokens,
        preempting the youngest other sequences under pool pressure.
        Oldest targets reserve first; a target that was itself evicted
        by an earlier reservation is skipped."""
        st = self.state
        if st.kvman is None:
            return
        for r, want in sorted(targets, key=lambda t: t[0].rid):
            if r.rid not in st.active:
                continue
            want = min(want, self.ecfg.max_len)
            while not st.kvman.ensure(r.slot, want):
                if not self.preempt_one(protect_rid=r.rid):
                    raise RuntimeError(
                        "KV page pool exhausted by a single sequence; "
                        "num_pages must be >= ceil(max_len/page_size)")

    # ------------------------------------------------------------------
    # prefill chunk planning
    # ------------------------------------------------------------------
    def plan_chunks(self) -> list[tuple[Request, int]]:
        """Pick this iteration's prefill work: each prefilling row gets
        up to one ``prefill_chunk`` of its remaining context, FCFS by
        rid, capped globally by ``mixed_prefill_budget`` tokens (0 = no
        cap).  Partial chunks are free — the chunk call has one static
        shape and masks per-row tails."""
        budget = self.ecfg.mixed_prefill_budget or None
        work: list[tuple[Request, int]] = []
        for r in sorted(self.state.active.values(), key=lambda r: r.rid):
            if not r.prefilling:
                continue
            n = min(r.n_ctx - r.pos, self.ecfg.prefill_chunk)
            if budget is not None:
                n = min(n, budget)
                if n <= 0:
                    break
                budget -= n
            work.append((r, n))
        return work

    # ------------------------------------------------------------------
    # decode batch bucketing
    # ------------------------------------------------------------------
    def bucket(self, n: int, compiled) -> int:
        """Decode batch bucket for n active sequences.

        Power-of-two rounding, with a compile-avoidance grace: a bucket
        nobody has compiled yet first borrows the smallest compiled
        bucket above it (correct — extra rows are padding) and only
        earns its own compile after ``bucket_compile_grace`` uses.  This
        keeps end-of-trace drain-down from compiling each small bucket
        for a handful of steps, while sustained low occupancy (a long
        low-rate phase, a straggler tail) still gets its fast bucket.
        ``compiled`` is the executor's set of already-built decode
        buckets.
        """
        if self.ecfg.bucket_mode == "fixed":
            return self.ecfg.max_batch
        b = min(_pow2(max(n, 1)), self.ecfg.max_batch)
        if b in compiled:
            return b
        bigger = [k for k in compiled if k > b]
        if not bigger:
            return b
        self._bucket_demand[b] = self._bucket_demand.get(b, 0) + 1
        if self._bucket_demand[b] > self.ecfg.bucket_compile_grace:
            return b
        return min(bigger)

    # ------------------------------------------------------------------
    # rebalance window
    # ------------------------------------------------------------------
    def rebalance_due(self) -> bool:
        """One local EPLB rebalance per ``rebalance_every`` decode
        steps.  With ``rebalance_defer_prefill`` (default) a window
        that lands while any chunked prefill is in flight stays pending
        until prefills drain: reshuffling the physical expert weights
        mid-prompt is *bitwise safe* (every replica of an expert holds
        identical weights — pinned by the mid-prefill rebalance
        regression test), but deferring keeps the reshuffle's weight
        copies out of a prompt's chunk-to-chunk critical path.  The
        deferral is bounded by one extra window: under sustained load
        prefills are almost always in flight, and an unbounded guard
        would starve rebalancing entirely."""
        ecfg, st = self.ecfg, self.state
        every = ecfg.rebalance_every
        if not every:
            return False
        if st.decode_steps % every == 0 and not self._rebalance_pending:
            self._rebalance_pending = True
            self._rebalance_pending_since = st.decode_steps
        if not self._rebalance_pending:
            return False
        if (ecfg.rebalance_defer_prefill and st.prefills_in_flight()
                and st.decode_steps - self._rebalance_pending_since
                < every):
            return False
        self._rebalance_pending = False
        return True
