"""Scheduling policy: admission, chunk planning, preemption, bucketing,
and the rebalance window.

Pure host-side decisions over :class:`repro.serving.state.EngineState`
— no jax, no device work.  The engine façade asks the scheduler *what*
to run each iteration and hands the chosen rows to the executor; this
separation is what lets the cluster layer drive many engines with
different placement policies without touching the jit path.

(The one device-touching exception is injected: ``copy_pages`` is the
executor's copy-on-write page copy, called at the moment admission
stages a boundary page — the copy must land before anything can evict
or write the source, so it cannot be deferred to the engine loop.)
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.serving.kv import pages_for
from repro.serving.prefix import PrefixMatch
from repro.serving.state import EngineState, Request


def _pow2(n: int) -> int:
    return 1 << max(0, (int(n) - 1).bit_length())


@dataclasses.dataclass
class AdmissionPlan:
    """One queued request's page-aware admission decision."""
    decision: str               # "admit" | "defer"
    n_ctx: int
    first_target: int           # tokens whose pages are reserved now
    match: Optional[PrefixMatch]
    need: int                   # fresh pages required for first_target
    budget: int                 # free + reclaimable available to it


class Scheduler:
    def __init__(self, ecfg, state: EngineState, slo, chunked: bool,
                 copy_pages: Optional[Callable] = None):
        self.ecfg = ecfg
        self.state = state
        self.slo = slo
        self.chunked = chunked
        self.copy_pages = copy_pages    # executor CoW copy (src, dst)
        self._bucket_demand: dict[int, int] = {}
        self._rebalance_pending = False
        self._rebalance_pending_since = 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def eligible_match(self, tokens) -> Optional[PrefixMatch]:
        """The ONE definition of a takeable prefix match — shared by
        admission (below) and the cluster's prefix-affinity dispatch
        (``ServingEngine.prefix_match_len``), so dispatch can never
        chase a match admission would refuse: context truncated to the
        engine's prefill cap, matches below ``prefix_min_tokens``
        rejected (a 1-token hit still costs a CoW page copy).  Pure
        peek: no LRU update."""
        st, ecfg = self.state, self.ecfg
        if st.prefix is None:
            return None
        n = min(len(tokens), ecfg.max_len - 1)
        m = st.prefix.match(np.asarray(tokens)[:n])
        return m if m.m >= max(ecfg.prefix_min_tokens, 1) else None

    def plan_admission(self, r: Request, qdepth: int) -> AdmissionPlan:
        """Page-aware admission policy (the ROADMAP's cost model over
        free pages, queue depth, and post-match suffix length).

        The request's longest cached prefix is matched first: skipped
        tokens need no fresh pages (full matched pages are shared;
        a token-level boundary match costs ONE copy-on-write page), so
        the *suffix after the match* is what admission must fund —
        pages for its first chunk now (``first_target``), or, on a full
        hit, the page its first decode token writes.

        Budget = free pages + reclaimable prefix pages (cache is always
        cheaper to drop than running work is to preempt), minus the
        matched pages this very request is about to take off the
        reclaimable list.  On top of the hard first-chunk need, the
        policy holds back ``admit_reserve_frac`` of the request's
        *future* page demand (context + expected output), decayed by
        queue depth: a shallow queue keeps slack so steady-state decode
        growth doesn't trigger preemption thrash, a deep queue admits
        greedily to drain (frac / (1 + qdepth) -> the plain first-chunk
        gate under backlog).  ``admit_reserve_frac=0`` (default) *is*
        the plain gate — bit-compatible with the PR-2 scheduler.

        Decisions: ``admit`` (reclaim happens lazily in
        ``EngineState.activate`` only if the free list alone falls
        short) or ``defer`` (stay queued; chunked mode scans past).
        """
        st, ecfg = self.state, self.ecfg
        ctx = r.context_tokens()
        n_ctx = min(len(ctx), ecfg.max_len - 1)
        match = self.eligible_match(ctx)
        m = match.m if match else 0
        if m < n_ctx:
            first_target = min(m + ecfg.prefill_chunk, n_ctx) \
                if self.chunked else n_ctx
        else:
            # full hit: no prefill — reserve through the first decode
            # write at position n_ctx
            first_target = min(n_ctx + 1, ecfg.max_len)
        shared = len(match.pages) if match else 0
        need = pages_for(first_target, ecfg.page_size) - shared
        kv = st.kvman
        if kv is None:
            return AdmissionPlan("admit", n_ctx, first_target, match,
                                 0, 0)
        # matched pages leave the reclaimable set the moment they are
        # mapped/pinned — budget against what is left
        consumed = 0
        if match:
            consumed = sum(1 for p in match.pages
                           if kv.refcount[p] == 0)
            if match.cow_src is not None \
                    and kv.refcount[match.cow_src] == 0:
                consumed += 1
        budget = kv.num_free + kv.num_reclaimable - consumed
        hold = 0
        if ecfg.admit_reserve_frac > 0.0:
            expected = min(n_ctx + 1 + r.max_new_tokens, ecfg.max_len)
            future = max(pages_for(expected, ecfg.page_size)
                         - pages_for(first_target, ecfg.page_size), 0)
            frac = ecfg.admit_reserve_frac / (1.0 + qdepth)
            hold = int(np.ceil(frac * future))
        decision = "admit" if need + hold <= budget else "defer"
        return AdmissionPlan(decision, n_ctx, first_target, match,
                             need, budget)

    def admit(self) -> list[Request]:
        """Admit waiting requests into free slots.

        Chunked prefill only needs pages for a request's FIRST chunk
        *after its longest cached prefix*, so a page-blocked request no
        longer blocks the whole queue: the scan continues past it and
        admits any later request whose plan says admit (slots stay
        strictly FCFS — running out of slots stops the scan).
        ``prefill_mode="wave"`` needs every context page up front and
        keeps the seed's strict head-of-line gate.

        A prefix hit commits here: shared pages mapped, LRU touched,
        the copy-on-write boundary page copied on device *immediately*
        (before any later admission could evict or recycle the source),
        and prefill starts at the match point — the skipped tokens
        never reach chunk planning, the prefill span, or the
        expert-load EWMA.
        """
        st = self.state
        admitted: list[Request] = []
        if not st.queue or not st.free_slots:
            return admitted
        qdepth = len(st.queue)
        remaining: deque[Request] = deque()    # deferred, scanned past
        while st.queue and st.free_slots:
            r = st.queue.popleft()
            plan = self.plan_admission(r, qdepth)
            if plan.decision == "defer":
                remaining.append(r)
                if not self.chunked:
                    break               # strict FCFS: wait for pages
                continue
            if plan.match is not None and st.prefix is not None:
                st.prefix.touch(plan.match)
                st.prefix.hits += 1
            elif st.prefix is not None:
                st.prefix.misses += 1
            cow = st.activate(r, plan.n_ctx, plan.first_target,
                              plan.match)
            if cow is not None:
                # the copy is semantically required: a zeroed boundary
                # page would silently break the hit==cold bit-exactness
                assert self.copy_pages is not None, (
                    "prefix-enabled scheduler needs the executor's CoW "
                    "page copy (copy_pages)")
                self.copy_pages(*cow)
                st.kvman.unpin(cow[0])
            admitted.append(r)
            self.slo.admitted(r.rid)
            if st.prefix is not None:
                # stamped on EVERY admission (0 on a miss): a hit
                # request preempted and readmitted cold must land in
                # the cold TTFT population, not keep a stale hit mark
                self.slo.prefix_hit(r.rid, r.prefix_hit_tokens)
                if r.prefix_hit_tokens and not r.prefilling:
                    # full hit: no prefill span at all — stamp its end
                    # so decode wait is still attributable
                    self.slo.prefill_done(r.rid)
        # splice the untouched tail back (skipped requests were earlier
        # in the queue, so relative order is preserved); O(1) when the
        # scan never started
        remaining.extend(st.queue)
        st.queue = remaining
        return admitted

    # ------------------------------------------------------------------
    # preemption / page reservation
    # ------------------------------------------------------------------
    def preempt_one(self, protect_rid: int) -> bool:
        """Evict the youngest active request (≠ protect_rid): free its
        pages + slot and requeue it for recompute-on-readmission.  A
        victim caught *between prefill chunks* releases every page it
        has written so far; readmission recomputes bitwise to the state
        an unpreempted run would have reached (the prefill-phase
        regression test) — including a victim holding shared prefix /
        copy-on-write pages, which simply drops its references and
        re-matches on readmission.  A victim caught mid-DECODE replays
        prompt+generated as context, which collapses the re-fed
        boundary token the continued run kept at position n_ctx — its
        continuation is correct-by-recompute but not bitwise the
        unpreempted one (seed semantics, unchanged)."""
        st = self.state
        victims = [r for r in st.active.values() if r.rid != protect_rid]
        if not victims:
            return False
        v = max(victims, key=lambda r: r.rid)
        st.evict(v)
        self.slo.preemptions += 1
        return True

    def reserve(self, targets: list[tuple[Request, int]]):
        """Grow each target row's page table to cover ``want`` tokens.
        Under pool pressure, reclaim unreferenced prefix-cache pages
        first (LRU), and only then preempt the youngest other
        sequences — dropping cache is free, recompute is not.  Oldest
        targets reserve first; a target that was itself evicted by an
        earlier reservation is skipped."""
        st = self.state
        if st.kvman is None:
            return
        for r, want in sorted(targets, key=lambda t: t[0].rid):
            if r.rid not in st.active:
                continue
            want = min(want, self.ecfg.max_len)
            while not st.kvman.ensure(r.slot, want):
                short = pages_for(want, self.ecfg.page_size) \
                    - st.kvman.owned(r.slot) - st.kvman.num_free
                if st.prefix is not None \
                        and st.prefix.reclaim(short) > 0:
                    continue
                if not self.preempt_one(protect_rid=r.rid):
                    raise RuntimeError(
                        "KV page pool exhausted by a single sequence; "
                        "num_pages must be >= ceil(max_len/page_size)")

    # ------------------------------------------------------------------
    # prefill chunk planning
    # ------------------------------------------------------------------
    def plan_chunks(self) -> list[tuple[Request, int]]:
        """Pick this iteration's prefill work: each prefilling row gets
        up to one ``prefill_chunk`` of its remaining context, FCFS by
        rid, capped globally by ``mixed_prefill_budget`` tokens (0 = no
        cap).  Partial chunks are free — the chunk call has one static
        shape and masks per-row tails.  Prefix-hit rows enter with
        ``pos`` already at the match point, so only the suffix is ever
        planned."""
        budget = self.ecfg.mixed_prefill_budget or None
        work: list[tuple[Request, int]] = []
        for r in sorted(self.state.active.values(), key=lambda r: r.rid):
            if not r.prefilling:
                continue
            n = min(r.n_ctx - r.pos, self.ecfg.prefill_chunk)
            if budget is not None:
                n = min(n, budget)
                if n <= 0:
                    break
                budget -= n
            work.append((r, n))
        return work

    # ------------------------------------------------------------------
    # decode batch bucketing
    # ------------------------------------------------------------------
    def bucket(self, n: int, compiled) -> int:
        """Decode batch bucket for n active sequences.

        Power-of-two rounding, with a compile-avoidance grace: a bucket
        nobody has compiled yet first borrows the smallest compiled
        bucket above it (correct — extra rows are padding) and only
        earns its own compile after ``bucket_compile_grace`` uses.  This
        keeps end-of-trace drain-down from compiling each small bucket
        for a handful of steps, while sustained low occupancy (a long
        low-rate phase, a straggler tail) still gets its fast bucket.
        ``compiled`` is the executor's set of already-built decode
        buckets.
        """
        if self.ecfg.bucket_mode == "fixed":
            return self.ecfg.max_batch
        b = min(_pow2(max(n, 1)), self.ecfg.max_batch)
        if b in compiled:
            return b
        bigger = [k for k in compiled if k > b]
        if not bigger:
            return b
        self._bucket_demand[b] = self._bucket_demand.get(b, 0) + 1
        if self._bucket_demand[b] > self.ecfg.bucket_compile_grace:
            return b
        return min(bigger)

    # ------------------------------------------------------------------
    # expert-pool residency gate
    # ------------------------------------------------------------------
    def gate_decode(self, pool) -> int:
        """Gate a decode-carrying step on expert-page residency: every
        page the prefetch plan named must be resident before the step
        runs, so planned pages the ``prefetch_depth`` budget deferred
        are fetched synchronously here.  The fetch time is attributed
        as a decode stall (``expert_gate``).  Returns the bytes
        fetched, which the engine charges to the step's cost model."""
        if pool is None:
            return 0
        nbytes = pool.flush_pending(kind="decode")
        if nbytes:
            self.slo.stall("expert_gate", pool.stall_seconds(nbytes))
        return nbytes

    # ------------------------------------------------------------------
    # rebalance window
    # ------------------------------------------------------------------
    def rebalance_due(self) -> bool:
        """One local EPLB rebalance per ``rebalance_every`` decode
        steps.  With ``rebalance_defer_prefill`` (default) a window
        that lands while any chunked prefill is in flight stays pending
        until prefills drain: reshuffling the physical expert weights
        mid-prompt is *bitwise safe* (every replica of an expert holds
        identical weights — pinned by the mid-prefill rebalance
        regression test), but deferring keeps the reshuffle's weight
        copies out of a prompt's chunk-to-chunk critical path.  The
        deferral is bounded by one extra window: under sustained load
        prefills are almost always in flight, and an unbounded guard
        would starve rebalancing entirely."""
        ecfg, st = self.ecfg, self.state
        every = ecfg.rebalance_every
        if not every:
            return False
        if st.decode_steps % every == 0 and not self._rebalance_pending:
            self._rebalance_pending = True
            self._rebalance_pending_since = st.decode_steps
        if not self._rebalance_pending:
            return False
        if (ecfg.rebalance_defer_prefill and st.prefills_in_flight()
                and st.decode_steps - self._rebalance_pending_since
                < every):
            return False
        self._rebalance_pending = False
        return True
