"""Fine-grained analytical roofline simulator (stand-in for the paper's
proprietary simulator, §VI-A).

Per MoE layer, per device g of an EP group:

  t_mem(g)  = (activated(g) * expert_weight_bytes / tp
               + tokens(g) * act_io_bytes) / HBM_bw
  t_comp(g) = tokens(g) * expert_flops / (tp * peak)
  t_layer   = max_g max(t_mem, t_comp)  +  t_dispatch + t_combine

i.e. runtime is set by the most-bottlenecked device (the paper's load
imbalance model), memory-bound whenever weight streaming dominates —
which makes the layer time proportional to *activated experts*, the
paper's central observation (§III-B).  Attention, dense FFN, collective
launch latency and link bandwidth are modeled the same way.

Routing statistics come from *actually running* our routers
(core.routing) on synthetic top-k traces with Zipf-skewed expert
popularity — the analogue of the paper's replayed vLLM traces.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import metrics as M
from repro.core.metrics import HardwareSpec
from repro.core.placement import build_placement, slots_for_ratio
from repro.core.types import Placement

import jax.numpy as jnp
from repro.core import routing as R


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    tp: int = 1     # chips acting as one EP rank (intra-expert TP)
    ep: int = 8     # EP ranks

    @property
    def chips(self) -> int:
        return self.tp * self.ep


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Synthetic trace knobs.

    alpha = Zipf skew of expert popularity (decode-heavy coding
    workloads are more skewed than prefill-heavy math, paper Fig. 8).
    domains = token clustering: real batches mix a few request domains,
    each exercising its own expert subset — i.i.d. sampling would
    unrealistically activate nearly every expert at k=8."""
    name: str = "humaneval-like"
    zipf_alpha: float = 1.2
    prompt_len: int = 1024
    gen_len: int = 2048
    domains: int = 4
    layer_decorrelation: bool = True
    seed: int = 0


class LayerTrace:
    """Fixed (permutation, domain-offsets) expert-popularity model for
    one MoE layer: .sample() draws correlated top-k batches; .loads()
    is the matching historical per-expert load EPLB replication sees."""

    def __init__(self, rng, num_experts: int, alpha: float,
                 domains: int = 1):
        self.n = num_experts
        self.alpha = alpha
        self.domains = max(domains, 1)
        self.perm = rng.permutation(self.n)
        self.offs = rng.integers(0, self.n, self.domains)
        base = 1.0 / np.power(np.arange(1, self.n + 1), alpha)
        self._dom_p = [np.roll(base, o) / base.sum() for o in self.offs]

    def sample(self, rng, tokens: int, k: int) -> np.ndarray:
        ids = np.empty((tokens, k), dtype=np.int64)
        tok_dom = rng.integers(0, self.domains, tokens)
        for t in range(tokens):
            ids[t] = self.perm[rng.choice(
                self.n, size=k, replace=False, p=self._dom_p[tok_dom[t]])]
        return ids

    def loads(self) -> np.ndarray:
        mix = np.mean(self._dom_p, axis=0)
        loads = np.empty(self.n)
        loads[self.perm] = mix
        return loads


def synth_topk_batch(rng, num_experts: int, tokens: int, k: int,
                     alpha: float, perm: Optional[np.ndarray] = None,
                     domains: int = 1):
    """[T, k] distinct expert ids per token (compat wrapper)."""
    tr = LayerTrace(rng, num_experts, alpha, domains)
    if perm is not None:
        tr.perm = perm
    return tr.sample(rng, tokens, k)


def _route_stats(cfg: ModelConfig, placement: Placement, ids: np.ndarray,
                 algo: str):
    """Run the real router; return (activated[G], tokens[G])."""
    idsj = jnp.asarray(ids, jnp.int32)
    hist = R.topk_histogram(idsj, cfg.num_experts)
    slots = R.route(algo, idsj, hist, jnp.asarray(placement.expert_slots),
                    jnp.asarray(placement.expert_num_replicas),
                    num_devices=placement.num_devices,
                    slots_per_device=placement.slots_per_device)
    g, s = placement.num_devices, placement.slots_per_device
    act = np.asarray(M.activated_per_device(slots, g, s))
    tok = np.asarray(M.tokens_per_device(slots, g, s))
    return act, tok


# ----------------------------------------------------------------------
# expert-FFN HBM traffic model (per grouped-matmul impl)
# ----------------------------------------------------------------------


def expert_ffn_traffic(impl: str, *, d: int, fe: int, n_up: int,
                       tile_m: int, n_tiles: int, live_tiles: int,
                       bytes_weight: float = 2.0,
                       bytes_act: float = 2.0) -> dict:
    """Analytic HBM bytes for one local expert-FFN over a pair buffer.

    The buffer holds ``n_tiles`` token tiles of ``tile_m`` rows,
    ``live_tiles`` of which reference a local expert (the rest are dead
    padding — METRO's no-drop capacity is ``T*k`` pairs, so dead tiles
    are common in the decode regime).  Per-impl accounting:

      ``fused``          — one-pass megakernel: each live tile streams
          its group's up+down weights once; x in / y out for live
          tiles only; the hidden NEVER touches HBM; dead tiles cost
          nothing.
      ``two_pass``       — this PR's dead-tile-skipping ragged /
          scan_tiles / pallas impls: weights stream per *live* tile in
          each of the two passes, but the ``[C, n_up*fe]`` hidden
          round-trips HBM between them (write h, read h for gating,
          write gated, read gated for the down pass) over the full
          buffer.
      ``two_pass_legacy``— the seed behavior: like ``two_pass`` but
          dead tiles also DMA weight tiles (``tile_group`` was clamped
          to ``s_loc-1``, so padding tiles fetched the last expert's
          weights in both passes).

    Returns ``{"weight_bytes", "act_bytes", "hidden_bytes", "total"}``.
    """
    f_up = n_up * fe
    w_group = (d * f_up + fe * d) * bytes_weight   # up + down per tile
    c = n_tiles * tile_m
    c_live = live_tiles * tile_m
    if impl == "fused":
        weight = live_tiles * w_group
        act = c_live * 2 * d * bytes_act           # x in + y out
        hidden = 0.0
    elif impl == "two_pass":
        weight = live_tiles * w_group
        act = c_live * 2 * d * bytes_act
        hidden = c * 2 * (f_up + fe) * bytes_act   # h w+r, gated w+r
    elif impl == "two_pass_legacy":
        weight = n_tiles * w_group                 # dead tiles DMA too
        act = c * 2 * d * bytes_act
        hidden = c * 2 * (f_up + fe) * bytes_act
    else:
        raise ValueError(f"unknown traffic impl {impl!r}")
    return {"weight_bytes": float(weight), "act_bytes": float(act),
            "hidden_bytes": float(hidden),
            "total": float(weight + act + hidden)}


def fused_weight_dma_tiles(tile_group, k_up_tiles: int,
                           k_down_tiles: int) -> dict:
    """Emulate the fused megakernel's weight-tile DMA count.

    Replays the kernel's BlockSpec index maps over the grid
    ``(n_tiles, k_up + k_down)`` with Pallas' revisit-skip semantics (a
    block whose index equals the previous grid step's is not
    refetched).  Dead tiles (``tile_group[i] == -1``) park both weight
    indices on the last live tile's blocks, so they fetch nothing.

    Returns ``{"dma_tiles"`` (k-tile-granular fetches), ``"m_tiles"``
    (token tiles that triggered any weight fetch), ``"live_tiles"}``.
    With all k-tile counts >= 2 and distinct groups per live tile,
    ``dma_tiles == live_tiles * (k_up + k_down)`` exactly; adjacent
    live tiles sharing a group with a single-k-tile operand can only
    *lower* the count (the repeated index is skipped too).

    An all-dead (but non-empty) grid is NOT free: Pallas index maps
    must name a block, so the dead tiles park on group 0's first
    up/down blocks and the pipeline physically prefetches each of
    them once (``dma_tiles == 2``, ``m_tiles == 1``) even though they
    feed no compute.  ``expert_ffn_traffic`` stays a *marginal-cost*
    model (0 bytes at ``live_tiles == 0``); this function counts the
    physical fetches.  An empty ``tile_group`` fetches nothing.
    """
    tg = np.asarray(tile_group, np.int64)
    n_live = int((tg >= 0).sum())
    if len(tg) == 0:
        return {"dma_tiles": 0, "m_tiles": 0, "live_tiles": 0}
    count = 0
    fetching = set()
    last_u = last_d = None
    for i in range(len(tg)):
        ie = max(min(i, n_live - 1), 0)
        g = max(int(tg[ie]), 0)
        live = i < n_live
        for j in range(k_up_tiles + k_down_tiles):
            # dead tiles park on the last live tile's FINAL indices —
            # the frozen phase component is what keeps a dead tile's
            # index constant across its own grid steps
            iu = (g, min(j, k_up_tiles - 1) if live else k_up_tiles - 1)
            idn = (g, max(j - k_up_tiles, 0) if live
                   else k_down_tiles - 1)
            if iu != last_u:
                count += 1
                fetching.add(i)
                last_u = iu
            if idn != last_d:
                count += 1
                fetching.add(i)
                last_d = idn
    return {"dma_tiles": count, "m_tiles": len(fetching),
            "live_tiles": n_live}


def make_roofline_step_cost(cfg: ModelConfig, impl: str, *,
                            k: Optional[int] = None, tile: int = 8,
                            hbm_bw: float = 8.0e11,
                            h2d_bw: float = 1.6e10,
                            base: float = 2e-4,
                            prefill_per_tok: float = 2e-5):
    """Virtual-clock ``step_cost(kind, n_tokens, stats)`` charging the
    per-impl expert-FFN HBM-bytes model instead of raw ``max_activated``.

    Decode (the memory-bound phase) is charged
    ``expert_ffn_traffic(impl)`` per MoE layer on the bottleneck
    device: ``live_tiles = stats["max_activated"]`` (each activated
    expert holds >= 1 resident token tile at decode batch sizes) and
    ``n_tiles = ceil(n_tokens * k / tile)`` buffer tiles (METRO's
    no-drop capacity) — so EPLB's extra activated experts *and* the
    impl's dead-tile / hidden-round-trip traffic both surface in the
    modeled latency, which is how the Pareto harness shows the fused
    kernel's headroom.  Prefill-carrying calls stay compute-bound
    (token-proportional), matching ``cluster.default_step_cost``.

    When the expert-weight pool is enabled the executor reports
    host<->HBM page traffic in the stats: demand misses and
    residency-gate flushes (``pool_miss_bytes`` + ``pool_gate_bytes``)
    are serial — the step cannot start until the weights land — while
    ``pool_prefetch_bytes`` overlaps the step's compute/HBM time via
    the double-buffered DMA pipeline, so it is charged as
    ``max(step, prefetch)``.  All three cross the host link at
    ``h2d_bw`` (PCIe-class, ~50x slower than HBM), which is what makes
    the tokens/s-vs-budget curves in ``bench_expert_paging`` bend.
    """
    assert impl in ("fused", "two_pass", "two_pass_legacy"), impl
    k = k or max(cfg.num_experts_per_tok, 1)
    kinds = cfg.layer_kinds()
    n_moe = sum(1 for _, f in kinds if f == "moe")
    # dense configs have no MoE layers: decode cost degenerates to the
    # base + token terms instead of phantom expert traffic
    moe_layers = (cfg.num_layers // len(kinds)) * n_moe
    n_up = 2 if cfg.gated_mlp else 1

    def step_cost(kind: str, n_tokens: int, stats: dict) -> float:
        demand = (float(stats.get("pool_miss_bytes", 0.0))
                  + float(stats.get("pool_gate_bytes", 0.0))) / h2d_bw
        prefetch = float(stats.get("pool_prefetch_bytes", 0.0)) / h2d_bw
        if kind != "decode":
            step = base + prefill_per_tok * n_tokens
            return max(step, prefetch) + demand
        act = int(stats["max_activated"])
        n_tiles = max(int(np.ceil(n_tokens * k / tile)), 1, act)
        tr = expert_ffn_traffic(
            impl, d=cfg.d_model, fe=cfg.expert_hidden, n_up=n_up,
            tile_m=tile, n_tiles=n_tiles, live_tiles=act)
        step = base + moe_layers * tr["total"] / hbm_bw \
            + 1e-5 * n_tokens
        return max(step, prefetch) + demand

    return step_cost


# ----------------------------------------------------------------------
# per-layer time model
# ----------------------------------------------------------------------


def decode_layer_breakdown(cfg: ModelConfig, hw: HardwareSpec,
                           par: ParallelismConfig, batch: int, ctx: int,
                           act: np.ndarray, tok: np.ndarray,
                           bytes_per_param: float = 2.0) -> dict:
    """One decode step through one (attention + MoE-FFN) layer.

    act/tok: per-EP-rank routing stats for this batch."""
    d, fe = cfg.d_model, cfg.expert_hidden
    n_mat = 3 if cfg.gated_mlp else 2
    chips = par.chips

    # ---- attention (DP over requests, KV cache read dominates) -------
    kv_heads = max(cfg.num_kv_heads, 1)
    kv_bytes_per_req = 2 * ctx * kv_heads * cfg.head_dim * bytes_per_param
    attn_w_bytes = (d * cfg.head_dim
                    * (cfg.num_heads + 2 * kv_heads)
                    + cfg.num_heads * cfg.head_dim * d) * bytes_per_param
    b_per_chip = max(batch / chips, 1e-9)
    t_attn_mem = (b_per_chip * kv_bytes_per_req
                  + attn_w_bytes / chips) / hw.hbm_bw
    attn_flops = (b_per_chip
                  * (2 * ctx * kv_heads * cfg.head_dim * 2
                     + 4 * d * cfg.num_heads * cfg.head_dim))
    t_attn = max(t_attn_mem, attn_flops / hw.peak_flops)

    # ---- MoE FFN: the paper's model --------------------------------
    w_bytes = n_mat * d * fe * bytes_per_param
    act_io = 2 * d * 2 * bytes_per_param
    t_mem = (act * w_bytes / par.tp + tok * act_io) / hw.hbm_bw
    flops = tok * 2.0 * n_mat * d * fe
    t_comp = flops / (par.tp * hw.peak_flops)
    t_ffn = float(np.max(np.maximum(t_mem, t_comp)))
    if cfg.num_shared_experts:
        sh_bytes = n_mat * d * fe * cfg.num_shared_experts \
            * bytes_per_param / chips
        sh_flops = batch * 2 * n_mat * d * fe * cfg.num_shared_experts \
            / chips
        t_ffn += max(sh_bytes / hw.hbm_bw, sh_flops / hw.peak_flops)

    # ---- dispatch + combine (all-gather + all-to-all/scatter) -------
    tok_bytes = batch * d * bytes_per_param
    t_disp = hw.collective_launch + tok_bytes / hw.link_bw / chips
    t_comb = hw.collective_launch + tok_bytes / hw.link_bw / chips

    return {"attn": t_attn, "ffn": t_ffn, "dispatch": t_disp,
            "combine": t_comb,
            "total": t_attn + t_ffn + t_disp + t_comb}


def simulate_decode_step(cfg: ModelConfig, hw: HardwareSpec,
                         par: ParallelismConfig, batch: int, ctx: int,
                         algo: str, placement: Placement,
                         wl: WorkloadConfig, rng,
                         routing_overhead: float = 26e-6) -> dict:
    """Time for one full-model decode step of `batch` tokens.

    routing_overhead: per-layer cost of Alg. 1 AT 1.5x replication —
    26us measured by the paper on A100 (§VI-B, Fig. 11: the cost grows
    with replication since lock contention and candidate counts scale
    with replicas); scaled linearly in (ratio - 1)/0.5 below.  Our TPU
    scalar-core kernel estimate is ~5us (sequential, no locks).
    At 1.0x replication no routing decision exists (paper §VI-A), so
    neither the overhead nor any algo difference applies."""
    if placement.replication_ratio <= 1.001:
        algo, routing_overhead = "single", 0.0
    routing_overhead *= min((placement.replication_ratio - 1.0) / 0.5, 1.0)
    kinds = cfg.layer_kinds()
    blocks = cfg.num_layers // len(kinds)
    n, g = cfg.num_experts, placement.num_devices
    spd = placement.slots_per_device
    t_total, t_ffn, max_act = 0.0, 0.0, 0
    for i, (mixer, ffn) in enumerate(kinds):
        if ffn == "moe":
            # per-layer expert popularity; EPLB replicates by the SAME
            # (historical) loads the trace follows — the paper's setup,
            # where hot experts hold many replicas and the round-robin
            # router spreads their tokens across all of them.
            trace = LayerTrace(rng, n, wl.zipf_alpha, wl.domains)
            placement_l = build_placement(n, g, spd, loads=trace.loads())
            ids = trace.sample(rng, batch, cfg.num_experts_per_tok)
            act, tok = _route_stats(cfg, placement_l, ids, algo)
            max_act = max(max_act, int(act.max()))
        else:
            act = tok = np.zeros(par.ep)
        br = decode_layer_breakdown(cfg, hw, par, batch, ctx, act, tok)
        if ffn == "dense":   # dense FFN: treat as 1 always-active expert
            n_mat = 3 if cfg.gated_mlp else 2
            w = n_mat * cfg.d_model * cfg.d_ff * 2.0 / par.chips
            f = batch * 2 * n_mat * cfg.d_model * cfg.d_ff / par.chips
            br["ffn"] = max(w / hw.hbm_bw, f / hw.peak_flops)
            br["total"] = br["attn"] + br["ffn"] + br["dispatch"] \
                + br["combine"]
        if ffn == "moe" and algo == "metro":
            br["total"] += routing_overhead  # Alg. 1 kernel cost (§VI-B)
        t_total += br["total"] * blocks
        t_ffn += br["ffn"] * blocks
    # lm head + embed
    head = 2 * cfg.d_model * cfg.vocab_size * 2.0 / par.chips
    t_total += max(head / hw.hbm_bw,
                   batch * head / 2 / par.chips / hw.peak_flops)
    return {"step_s": t_total, "ffn_s": t_ffn, "max_activated": max_act}


def simulate_prefill_step(cfg: ModelConfig, hw: HardwareSpec,
                          par: ParallelismConfig, tokens: int,
                          algo: str, placement: Placement,
                          wl: WorkloadConfig, rng) -> dict:
    """Chunked-prefill step over `tokens` tokens (compute-bound path).

    Token balance (what EPLB optimizes) sets the bottleneck device."""
    kinds = cfg.layer_kinds()
    blocks = cfg.num_layers // len(kinds)
    n_mat = 3 if cfg.gated_mlp else 2
    d = cfg.d_model
    t = 0.0
    for i, (mixer, ffn) in enumerate(kinds):
        if ffn == "moe":
            ids = synth_topk_batch(
                rng, cfg.num_experts, min(tokens, 2048),
                cfg.num_experts_per_tok, wl.zipf_alpha)
            act, tok = _route_stats(cfg, placement, ids, algo)
            scale = tokens / min(tokens, 2048)
            fe = cfg.expert_hidden
            flops = tok * scale * 2 * n_mat * d * fe
            w_bytes = act * n_mat * d * fe * 2.0 / par.tp
            tmax = float(np.max(np.maximum(
                flops / (par.tp * hw.peak_flops), w_bytes / hw.hbm_bw)))
        else:
            f = tokens * 2 * n_mat * d * cfg.d_ff / par.chips
            tmax = f / hw.peak_flops
        # attention: flops-bound at prefill
        att = tokens * (4 * d * cfg.num_heads * cfg.head_dim
                        + 2 * 2 * wl.prompt_len * cfg.num_heads
                        * cfg.head_dim) / par.chips
        t += (tmax + att / hw.peak_flops
              + 2 * hw.collective_launch) * blocks
    return {"step_s": t}


def simulate_serving(cfg: ModelConfig, hw: HardwareSpec,
                     par: ParallelismConfig, wl: WorkloadConfig, *,
                     algo: str, replication_ratio: float,
                     decode_batch: int = 1024, prefill_chunk: int = 8192,
                     n_requests: int = 64, ctx: Optional[int] = None,
                     seed: int = 0) -> dict:
    """Co-deployed prefill+decode serving (paper Figs. 9/10).

    Placement/replication is EPLB in all cases (paper: both routers use
    EPLB placement); `algo` selects the *decode* router; prefill always
    uses EPLB routing."""
    rng = np.random.default_rng(seed)
    spd = slots_for_ratio(cfg.num_experts, par.ep, replication_ratio)
    loads = 1.0 / np.power(
        np.arange(1, cfg.num_experts + 1), wl.zipf_alpha)
    placement = build_placement(cfg.num_experts, par.ep, spd,
                                loads=rng.permutation(loads))
    ctx = ctx or (wl.prompt_len + wl.gen_len // 2)

    # prefill: total prompt tokens in chunks (EPLB routing, paper setup)
    total_prompt = n_requests * wl.prompt_len
    n_chunks = int(np.ceil(total_prompt / prefill_chunk))
    t_prefill = sum(
        simulate_prefill_step(cfg, hw, par, prefill_chunk, "eplb",
                              placement, wl, rng)["step_s"]
        for _ in range(min(n_chunks, 4))) / min(n_chunks, 4) * n_chunks

    # decode: gen_len steps at the configured global batch
    sample_steps = 4
    dec = [simulate_decode_step(cfg, hw, par, decode_batch, ctx, algo,
                                placement, wl, rng)
           for _ in range(sample_steps)]
    t_step = float(np.mean([d["step_s"] for d in dec]))
    max_act = int(np.max([d["max_activated"] for d in dec]))
    n_steps = wl.gen_len
    t_decode = t_step * n_steps

    total_tokens = n_requests * wl.prompt_len + decode_batch * n_steps
    wall = t_prefill + t_decode
    return {
        "tpot_s": t_step,
        "ttft_s": t_prefill / max(n_chunks, 1),
        "decode_tput": decode_batch / t_step,
        "total_token_throughput": total_tokens / wall,
        "max_activated": max_act,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
    }
