from repro.sim.roofline import (
    ParallelismConfig, WorkloadConfig, simulate_decode_step,
    simulate_prefill_step, simulate_serving, synth_topk_batch,
    decode_layer_breakdown, expert_ffn_traffic, fused_weight_dma_tiles,
    make_roofline_step_cost)

__all__ = [
    "ParallelismConfig", "WorkloadConfig", "simulate_decode_step",
    "simulate_prefill_step", "simulate_serving", "synth_topk_batch",
    "decode_layer_breakdown", "expert_ffn_traffic",
    "fused_weight_dma_tiles", "make_roofline_step_cost",
]
