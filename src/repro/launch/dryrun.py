import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory/cost/collective analysis.

The two lines above MUST run before any jax import — jax locks the
device count at first init.  Do not set that flag anywhere global.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
      --shape decode_32k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--skip-existing]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ASSIGNED_ARCHS, SHAPES, cell_applicable,
                           get_config, input_specs)
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as RL
from repro.launch.steps import (
    StepConfig, default_slots_per_device, make_placement, make_prefill_step,
    make_serve_step, make_train_step, sanitize_specs, serve_cache_pspecs,
    train_shardings, tree_named, batch_pspecs, serve_shardings)
from repro.models import lm as LM
from repro.sharding.policy import make_dist, param_pspecs
from repro.training.optimizer import adamw_init

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _specs_tree(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               *, replication_ratio: float = 1.25, algo: str = "metro",
               ep_mode: str = "paper", attn_chunk: int = 1024,
               remat: bool = True, microbatches: int = 0,
               remat_policy: str = "dots_no_batch",
               kv_dtype: str = "bfloat16"):
    """Lower + compile one cell; returns the artifact dict."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    okay, why = cell_applicable(cfg, shape)
    if not okay:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    # training uses no serving replication (R = next multiple of EP);
    # serving replicates per the paper (default 1.25x)
    ratio = 1.0 if shape.kind == "train" else replication_ratio
    spd = default_slots_per_device(cfg, mesh.shape["model"], ratio)
    dist = make_dist(mesh, slots_per_device=spd, ep_mode=ep_mode)
    # grad-accumulate so one microbatch ~= 1 sequence per data row
    dp = chips // mesh.shape["model"]
    micro = microbatches or (
        max(shape.global_batch // dp, 1) if shape.kind == "train" else 1)
    sc = StepConfig(cfg=cfg, dist=dist, algo_decode=algo,
                    replication_ratio=ratio,
                    attn_chunk=attn_chunk, remat=remat,
                    microbatches=micro, remat_policy=remat_policy,
                    kv_dtype=kv_dtype,
                    long_context=(shape_name == "long_500k"))

    placement = make_placement(sc)
    re_ = placement.replica_expert if placement else None
    params_shape = jax.eval_shape(
        lambda: LM.init_lm(cfg, jax.random.PRNGKey(0), dist,
                           replica_expert=re_))
    from repro.launch.steps import step_pspecs
    pspecs = step_pspecs(sc, params_shape, fsdp=False)
    routing_shape = (
        jax.eval_shape(lambda: LM.build_lm_routing(cfg, placement))
        if cfg.is_moe else {})

    binputs = input_specs(cfg, shape)
    t0 = time.time()

    if shape.kind == "train":
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape, sc.opt))
        bspecs = batch_pspecs(cfg, dist, binputs)
        in_sh, out_sh = train_shardings(sc, params_shape, opt_shape, bspecs)
        step = make_train_step(sc)
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(0, 1)).lower(
            params_shape, opt_shape, binputs, routing_shape)
    elif shape.kind == "prefill":
        bspecs = batch_pspecs(cfg, dist, binputs)
        cache_shape = jax.eval_shape(
            lambda: LM.init_cache(cfg, dist, shape.global_batch,
                                  shape.seq_len))
        cspecs = sanitize_specs(
            serve_cache_pspecs(cfg, dist, sc.long_context), cache_shape,
            dist)
        step = make_prefill_step(sc)
        in_sh = (tree_named(dist, pspecs), tree_named(dist, bspecs),
                 tree_named(dist, cspecs), None)
        out_sh = (None, tree_named(dist, cspecs), None)
        lowered = jax.jit(step, in_shardings=in_sh,
                          out_shardings=out_sh).lower(
            params_shape, binputs, cache_shape, routing_shape)
    else:  # decode
        cache_shape = jax.eval_shape(
            lambda: LM.init_cache(cfg, dist, shape.global_batch,
                                  shape.seq_len,
                                  dtype=jnp.dtype(sc.kv_dtype)))
        cspecs = sanitize_specs(
            serve_cache_pspecs(cfg, dist, sc.long_context), cache_shape,
            dist)
        in_sh, out_sh = serve_shardings(sc, params_shape, cspecs,
                                        shape.global_batch)
        step = make_serve_step(sc)
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(3,)).lower(
            params_shape, binputs["tokens"], binputs["pos"], cache_shape,
            routing_shape)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # --- analyses ---
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # CPU backend may not implement it
        mem_d = {"error": str(e)}
    try:
        cost_list = compiled.cost_analysis()
        cost = cost_list[0] if isinstance(cost_list, (list, tuple)) \
            else cost_list
        cost = dict(cost)
    except Exception as e:
        cost = {"error": str(e)}

    hlo = compiled.as_text()
    from repro.launch.hlo_cost import analyze_hlo
    hc = analyze_hlo(hlo)
    mf = RL.model_flops_estimate(cfg, shape)
    # trip-count-aware per-device costs from the HLO walker (XLA's own
    # cost_analysis counts while bodies once — useless under scan)
    terms = RL.roofline_terms(
        {"flops": hc.flops, "bytes accessed": hc.dot_bytes},
        hc.collective_bytes, chips, mf).as_dict()
    terms["while_loops"] = hc.while_loops
    terms["unknown_trip_loops"] = hc.unknown_trip_loops
    coll = {k: float(v) for k, v in hc.collective_bytes.items()}

    art = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "status": "ok",
        "replication_ratio": placement.replication_ratio if placement else None,
        "slots_per_device": spd if cfg.is_moe else None,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "collective_bytes": coll,
        "roofline": terms,
        "params": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--replication-ratio", type=float, default=1.25)
    ap.add_argument("--algo", default="metro", choices=["metro", "eplb",
                                                        "single"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--remat-policy", default="dots_no_batch")
    ap.add_argument("--kv-dtype", default="bfloat16")
    ap.add_argument("--out-dir", default=str(ART))
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or
                               (args.all and not args.multi_pod)) else \
        [args.multi_pod]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = out_dir / f"{tag}.json"
            if args.skip_existing and path.exists():
                print(f"[skip-existing] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                art = lower_cell(arch, shape, mp,
                                 replication_ratio=args.replication_ratio,
                                 algo=args.algo,
                                 microbatches=args.microbatches,
                                 remat_policy=args.remat_policy,
                                 kv_dtype=args.kv_dtype)
            except Exception as e:
                art = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": str(e),
                       "traceback": traceback.format_exc()}
                failures += 1
            path.write_text(json.dumps(art, indent=2, default=str))
            status = art["status"]
            extra = ""
            if status == "ok":
                r = art.get("roofline", {})
                extra = (f" compile={art['compile_s']}s "
                         f"bottleneck={r.get('bottleneck')}")
                mem = art["memory_analysis"]
                if "temp_size_in_bytes" in mem:
                    per_dev = (mem.get("argument_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0))
                    extra += f" bytes/dev={per_dev / 1e9:.2f}GB"
            elif status == "error":
                extra = " " + art["error"][:200]
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
