"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2 pods x 256 = 512 chips ("pod", "data", "model");
    the "pod" axis crosses DCN and composes with "data" for batch
    sharding; "model" carries TP/EP within a pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (Pareto sweeps use TPxEP variants)."""
    return jax.make_mesh(shape, axes)
