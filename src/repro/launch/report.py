"""Roofline report generator: artifacts/dryrun/*.json -> markdown table.

Per (arch x shape x mesh): the three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS (useful fraction), and the roofline
fraction = model-flops-time / dominant-term-time (how close the step is
to the hardware bound given its useful work).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.launch.roofline import PEAK_FLOPS

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def load_all(art_dir=ART):
    rows = []
    for p in sorted(Path(art_dir).glob("*.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def roofline_fraction(r) -> float:
    """model_flops / (chips*peak) vs the dominant term: the fraction of
    the roofline-limited step time that is useful model compute."""
    rf = r.get("roofline", {})
    if "compute_s" not in rf:
        return 0.0
    ideal = rf["model_flops"] / (r["chips"] * PEAK_FLOPS)
    dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    return ideal / dom if dom else 0.0


def one_liner(r) -> str:
    rf = r.get("roofline", {})
    mem = r.get("memory_analysis", {})
    per_dev = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 1e9
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{rf.get('compute_s', 0)*1e3:.2f} | "
            f"{rf.get('memory_s', 0)*1e3:.2f} | "
            f"{rf.get('collective_s', 0)*1e3:.2f} | "
            f"{rf.get('bottleneck','-')} | "
            f"{rf.get('useful_fraction', 0):.3f} | "
            f"{roofline_fraction(r):.3f} | {per_dev:.1f} |")


def main():
    rows = load_all(sys.argv[1] if len(sys.argv) > 1 else ART)
    print("| arch | shape | mesh | compute ms | memory ms | collective "
          "ms | bottleneck | useful (6ND/HLO) | roofline frac | GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    skipped = []
    for r in rows:
        if r["status"] == "skipped":
            skipped.append(r)
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"ERROR: {r.get('error','')[:60]} ||||||||")
            continue
        print(one_liner(r))
    print()
    for r in skipped:
        print(f"- skipped {r['arch']} x {r['shape']} ({r['mesh']}): "
              f"{r['reason']}")


if __name__ == "__main__":
    main()
