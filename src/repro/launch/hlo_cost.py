"""Trip-count-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE —
useless for scan-over-layers models (a 56-block scan under-counts 56x).
This walker parses the optimized HLO, propagates loop-trip multipliers
(``backend_config={"known_trip_count":{"n":...}}``) through the
computation call graph (while bodies, fusions, calls, conditionals), and
accumulates:

  * flops            — from dot ops: 2 * |result| * |contracted dims|
  * dot_bytes        — lhs+rhs+result bytes of every dot (the
                       weight-streaming / activation dataflow measure
                       that the memory roofline term cares about)
  * collective_bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       per kind

All values are PER DEVICE (the SPMD module is per-device).

bf16 correction: the CPU backend's float normalization promotes every
in-program bf16 tensor to f32 *after* SPMD partitioning (verified
against the post-spmd-partitioning pass dump: all cross-device
collectives are bf16 as written).  On TPU these stay bf16, so with
``assume_bf16_compute`` (default) f32 tensors are counted at 2
bytes/element for the dataflow/collective byte measures.  Genuinely-f32
buffers in our programs (optimizer moments, grad accumulators, loss
scalars) either never cross the ICI or are negligible.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_DTYPE_BYTES_BF16C = dict(_DTYPE_BYTES, f32=2, f64=2)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# start of a computation definition: `%name (args) -> type {`  or ENTRY
# (args may contain nested parens for tuple types)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
# an op definition inside a computation
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{}\/ ]+?)\s+"
    r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":{"n":"?(\d+)"?}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations={([^}]*)}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims={([\d,]*)}")


def _parse_shape(type_str: str):
    """-> list of (dtype, dims) for (possibly tuple) type strings."""
    return [(t, tuple(int(x) for x in d.split(",") if x.strip()))
            for t, d in _SHAPE_RE.findall(type_str)]


def _nbytes(type_str: str, table=_DTYPE_BYTES) -> int:
    return sum(table.get(t, 4) * _prod(d)
               for t, d in _parse_shape(type_str))


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class HloCost:
    flops: float
    dot_bytes: float
    collective_bytes: dict[str, float]
    while_loops: int
    unknown_trip_loops: int

    @property
    def collective_total(self) -> float:
        return sum(v for k, v in self.collective_bytes.items()
                   if k in _COLLECTIVES)


def analyze_hlo(text: str, assume_bf16_compute: bool = True) -> HloCost:
    table = _DTYPE_BYTES_BF16C if assume_bf16_compute else _DTYPE_BYTES
    # ---- pass 1: computations, ops, shapes -------------------------------
    comp_ops: dict[str, list[str]] = defaultdict(list)  # comp -> op lines
    op_shape: dict[str, str] = {}                       # op name -> type str
    current = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        mc = _COMP_RE.match(line)
        if mc and line.endswith("{"):
            current = mc.group(1)
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            name, type_str, _op = mo.groups()
            op_shape[name] = type_str
            comp_ops[current].append(line)

    # ---- pass 2: call graph with multipliers ----------------------------
    # edges: caller -> (callee, weight)
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    n_while = 0
    n_unknown = 0
    for comp, ops in comp_ops.items():
        for line in ops:
            mo = _OP_RE.match(line)
            op = mo.group(3)
            if op == "while":
                n_while += 1
                trips = _TRIP_RE.search(line)
                n = int(trips.group(1)) if trips else 1
                if not trips:
                    n_unknown += 1
                b = _BODY_RE.search(line)
                c = _COND_RE.search(line)
                if b:
                    edges[comp].append((b.group(1), float(n)))
                if c:
                    edges[comp].append((c.group(1), float(n + 1)))
            elif op == "conditional":
                mb = _BRANCHES_RE.search(line)
                if mb:
                    for br in re.findall(r"%?([\w.\-]+)", mb.group(1)):
                        edges[comp].append((br, 1.0))
            else:
                for m in (_CALLS_RE.search(line), _TOAPPLY_RE.search(line)):
                    if m:
                        edges[comp].append((m.group(1), 1.0))

    # entry = computation never called by others
    called = {c for outs in edges.values() for c, _ in outs}
    entries = [c for c in comp_ops if c not in called]
    mult: dict[str, float] = defaultdict(float)
    for e in entries:
        mult[e] += 1.0
    # propagate (call graph is a DAG; iterate to fixpoint over topological
    # order approximated by repeated relaxation)
    order = list(comp_ops.keys())
    for _ in range(len(order)):
        changed = False
        new = defaultdict(float)
        for e in entries:
            new[e] = 1.0
        for comp in order:
            if mult[comp] == 0:
                continue
            for callee, w in edges[comp]:
                new[callee] += mult[comp] * w
        for k in set(new) | set(mult):
            if abs(new[k] - mult[k]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break

    # op name -> its operand names + op kind (for fusion lookthrough)
    op_def: dict[str, tuple[str, list[str]]] = {}
    for comp, ops in comp_ops.items():
        for line in ops:
            mo = _OP_RE.match(line)
            nm, _t, opk = mo.groups()
            paren = line[line.find("(") + 1:line.rfind(")")]
            op_def[nm] = (opk, re.findall(r"%([\w.\-]+)", paren))

    def _operand_bytes(name: str) -> int:
        """HBM bytes behind a dot operand: look through one level of
        fusion/convert/bitcast/copy/transpose to the buffers actually
        read (e.g. an fp8 KV cache feeding a dequant-convert fusion is
        charged at 1 byte/elem, not the widened compute dtype).  Taking
        the min keeps slice-style fusions (inputs >> output) charged at
        the sliced size while narrowing converts win."""
        direct = _nbytes(op_shape.get(name, ""), table)
        kind, srcs = op_def.get(name, ("", []))
        if kind in ("fusion", "convert", "bitcast", "copy", "transpose",
                    "reshape") and srcs:
            thru = sum(_nbytes(op_shape.get(s, ""), table) for s in srcs)
            return min(direct, thru)
        return direct

    # ---- pass 3: accumulate costs ----------------------------------------
    flops = 0.0
    dot_bytes = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll["count"] = 0.0
    for comp, ops in comp_ops.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        for line in ops:
            mo = _OP_RE.match(line)
            name, type_str, op = mo.groups()
            if op == "dot":
                res = _parse_shape(type_str)
                if not res:
                    continue
                out_elems = _prod(res[0][1])
                # contraction size from lhs shape + contracting dims
                paren = line[line.find("(") + 1:]
                operands = re.findall(r"%([\w.\-]+)", paren)
                cm = _CONTRACT_RE.search(line)
                k_elems = 1
                if cm and operands:
                    lhs_shape = _parse_shape(op_shape.get(operands[0], ""))
                    if lhs_shape:
                        dims = lhs_shape[0][1]
                        for ci in (int(x) for x in cm.group(1).split(",")
                                   if x.strip()):
                            if ci < len(dims):
                                k_elems *= dims[ci]
                flops += m * 2.0 * out_elems * k_elems
                ob = sum(_operand_bytes(o) for o in operands[:2])
                dot_bytes += m * (ob + _nbytes(type_str, table))
            else:
                kind = next((k for k in _COLLECTIVES
                             if op == k or op.startswith(k + "-")), None)
                if kind:
                    paren = line[line.find("(") + 1:line.rfind(")")]
                    operands = re.findall(r"%([\w.\-]+)", paren)
                    nb = sum(_nbytes(op_shape.get(o, ""), table)
                             for o in operands)
                    if nb == 0:
                        nb = _nbytes(type_str, table)
                    coll[kind] += m * nb
                    coll["count"] += m
    coll["total"] = sum(coll[k] for k in _COLLECTIVES)
    return HloCost(flops=flops, dot_bytes=dot_bytes, collective_bytes=coll,
                   while_loops=n_while, unknown_trip_loops=n_unknown)
