"""Serving launcher: run the continuous-batching engine on a synthetic
request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b \
      --reduced --requests 8 --algo metro
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import EngineConfig, ServingEngine
from repro.sharding.policy import make_dist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--algo", default="metro",
                    choices=["metro", "eplb", "single"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--replication", type=float, default=1.25)
    ap.add_argument("--rebalance-every", type=int, default=64)
    ap.add_argument("--ep", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    spd = (slots_for_ratio(cfg.num_experts, args.ep, args.replication)
           if cfg.is_moe else 1)
    dist = make_dist(None, ep_size=args.ep, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, args.ep, spd)
                 if cfg.is_moe else None)
    params = init_lm(cfg, jax.random.PRNGKey(args.seed), dist,
                     replica_expert=placement.replica_expert
                     if placement else None)
    eng = ServingEngine(cfg, dist, params, EngineConfig(
        max_batch=args.max_batch, max_len=args.max_len,
        decode_algo=args.algo, rebalance_every=args.rebalance_every,
        replication_ratio=args.replication))
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        n = int(rng.integers(4, min(32, args.max_len // 2)))
        eng.submit(rng.integers(0, cfg.vocab_size, n), args.gen)
    summary = eng.run()
    for k, v in summary.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
