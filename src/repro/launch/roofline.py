"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the methodology:
    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the post-SPMD optimized HLO text: we sum
*operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (operand shapes are resolved from the
defining ops in the same pass).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# `%name = dtype[shape]{layout} op-name(...operands...)`
_DEF_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+([\w\-]+)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    # pass 1: result sizes of every named op
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, tuple_shapes, dtype, dims, _op = m.groups()
        if tuple_shapes is not None:
            total = sum(_shape_bytes(t, d)
                        for t, d in _SHAPE_RE.findall(tuple_shapes))
            sizes[name] = total
        else:
            sizes[name] = _shape_bytes(dtype, dims)

    # pass 2: collective ops -> sum their operand sizes
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(5)
        kind = next((k for k in _COLLECTIVES
                     if op == k or op.startswith(k + ".")), None)
        if kind is None:
            continue
        # operands: %names inside the parens
        paren = line[line.find("(") + 1:line.rfind(")")]
        ops = re.findall(r"%([\w.\-]+)", paren)
        nbytes = sum(sizes.get(o, 0) for o in ops)
        if nbytes == 0:
            # fall back to result size (operands may be inlined consts)
            name = m.group(1)
            nbytes = sizes.get(name, 0)
        out[kind] += float(nbytes)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_fraction: float

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(cost: dict[str, Any], collective: dict[str, float],
                   chips: int, model_flops: float) -> RooflineTerms:
    """cost_analysis()/HLO text on this backend describe the PER-DEVICE
    SPMD module (calibrated against a known matmul), so
    per_device_X / bw == global_X / (chips * bw) — the prompt's formula
    with both sides divided by `chips`."""
    flops_dev = float(cost.get("flops", 0.0))
    nbytes_dev = float(cost.get("bytes accessed", 0.0))
    cbytes_dev = float(collective.get("total", 0.0))
    t_c = flops_dev / PEAK_FLOPS
    t_m = nbytes_dev / HBM_BW
    t_n = cbytes_dev / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    bottleneck = max(terms, key=terms.get)
    flops_global = flops_dev * chips
    return RooflineTerms(
        flops=flops_global, bytes_accessed=nbytes_dev * chips,
        collective_bytes=cbytes_dev * chips,
        chips=chips, compute_s=t_c, memory_s=t_m, collective_s=t_n,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_fraction=model_flops / flops_global if flops_global else 0.0)


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D for training; 2*N*D for single forward (prefill);
    2*N_active*D for decode (D = tokens processed)."""
    if shape.kind == "train":
        n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per request
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    return 2.0 * n * shape.global_batch
