"""Training launcher.

Local CPU run (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
      --reduced --steps 50

Production: run under your TPU job launcher with jax.distributed
initialized per host; the mesh and shardings come from launch.steps.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.core import slots_for_ratio
from repro.data.pipeline import DataConfig
from repro.launch.steps import StepConfig
from repro.sharding.policy import make_dist
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ep", type=int, default=4,
                    help="virtual EP group size on CPU")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use make_production_mesh (requires devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    if args.production_mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        spd = (slots_for_ratio(cfg.num_experts, mesh.shape["model"], 1.0)
               if cfg.is_moe else 1)
        dist = make_dist(mesh, slots_per_device=spd)
    else:
        spd = (slots_for_ratio(cfg.num_experts, args.ep, 1.0)
               if cfg.is_moe else 1)
        dist = make_dist(None, ep_size=args.ep, slots_per_device=spd)

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.global_batch)
    tc = TrainConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir)
    sc = StepConfig(cfg=cfg, dist=dist, remat=bool(args.production_mesh),
                    fsdp=bool(args.production_mesh),
                    microbatches=args.microbatches,
                    opt=AdamWConfig(lr=args.lr))
    train(cfg, dist, dc, tc, sc=sc)


if __name__ == "__main__":
    main()
