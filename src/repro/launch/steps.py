"""Step-function builders: train_step / prefill_step / serve_step with
full input/param/cache shardings for a given (arch x shape x mesh).

These are what the dry-run lowers and what launch/train.py and the
serving engine execute.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeCell
from repro.core import build_placement, slots_for_ratio
from repro.models import lm as LM
from repro.sharding.policy import Dist, param_pspecs
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class StepConfig:
    """Everything needed to build and shard one step function."""
    cfg: ModelConfig
    dist: Dist
    algo_decode: str = "metro"      # the paper's technique (decode phase)
    algo_train: str = "eplb"        # token-balanced for compute-bound
    moe_impl: str = "ragged"
    remat: bool = True
    replication_ratio: float = 1.25
    opt: AdamWConfig = AdamWConfig(moment_dtype="bfloat16")
    attn_chunk: int = 1024
    long_context: bool = False      # shard KV sequence over data axes
    microbatches: int = 1           # grad-accumulation steps per train step
    fsdp: bool = True               # ZeRO-3-style param/opt sharding (train)
    remat_policy: str = "dots_no_batch"  # dots_no_batch | dots | nothing
    kv_dtype: str = "bfloat16"      # bfloat16 | float8_e4m3fn (fp8 KV cache)


def kv_needs_replication(cfg: ModelConfig, dist: Dist) -> bool:
    if not dist.mesh or cfg.num_heads <= 0:
        return False
    from repro.models.layers import attn_dims
    return attn_dims(cfg, dist.ep_size).kv % dist.ep_size != 0


def step_pspecs(sc: StepConfig, tree, *, fsdp=None, kv_rep=None):
    """kv replication removes per-layer K/V activation gathers in train;
    in decode it would instead ADD wk/wv weight re-reads on every chip
    (weight-streaming-bound), so it is train-only by default."""
    use_fsdp = sc.fsdp if fsdp is None else fsdp
    if kv_rep is None:
        kv_rep = use_fsdp and kv_needs_replication(sc.cfg, sc.dist)
    return param_pspecs(tree, sc.dist, fsdp=use_fsdp, kv_replicated=kv_rep)


def make_placement(sc: StepConfig):
    cfg, dist = sc.cfg, sc.dist
    if not cfg.is_moe:
        return None
    return build_placement(cfg.num_experts, dist.ep_size,
                           dist.slots_per_device)


def default_slots_per_device(cfg: ModelConfig, ep_size: int,
                             ratio: float) -> int:
    if not cfg.is_moe:
        return 1
    return slots_for_ratio(cfg.num_experts, ep_size, ratio)


# ----------------------------------------------------------------------
# sharding helpers
# ----------------------------------------------------------------------


def _ns(dist: Dist, spec: P):
    return NamedSharding(dist.mesh, spec) if dist.mesh else None


def batch_pspecs(cfg: ModelConfig, dist: Dist, batch_tree):
    """Shard the batch dim over (pod, data); fall back when indivisible."""
    def one(leaf):
        return dist.spec(leaf, dist.dp_axes,
                         *([None] * (len(leaf.shape) - 1)))
    return jax.tree.map(one, batch_tree)


def tree_named(dist: Dist, spec_tree):
    if dist.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(dist.mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# ----------------------------------------------------------------------
# train step
# ----------------------------------------------------------------------


def make_train_step(sc: StepConfig):
    cfg, dist = sc.cfg, sc.dist
    m = sc.microbatches

    def loss_fn(p, batch, routing):
        return LM.lm_loss(cfg, dist, p, batch, routing=routing,
                          algo=sc.algo_train, moe_impl=sc.moe_impl,
                          remat=sc.remat, chunk=sc.attn_chunk,
                          remat_policy=sc.remat_policy)

    def train_step(params, opt_state, batch, routing):
        # differentiate w.r.t. the bf16 compute copy: every gradient
        # collective (per-microbatch reduce-scatters, DP all-reduces)
        # then moves bf16 instead of f32 — 2x less ICI traffic (perf
        # iteration, EXPERIMENTS.md §Perf). Accumulation stays f32.
        bf16_params = LM.cast_params(params)
        if dist.mesh is not None:
            # pin the bf16 copy to the param sharding so XLA gathers
            # (fwd) and reduce-scatters (bwd) in bf16, not on the f32
            # master at the use site
            bf16_params = jax.lax.with_sharding_constraint(
                bf16_params, tree_named(dist, step_pspecs(sc, params)))

        if m == 1:
            (loss, stats), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(bf16_params, batch, routing)
        else:
            # gradient accumulation over microbatches: activations for
            # only one microbatch are live at a time
            mb = jax.tree.map(
                lambda a: a.reshape((m, a.shape[0] // m) + a.shape[1:]),
                batch)

            def acc(carry, mbatch):
                g_acc, l_acc = carry
                (l, st), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(bf16_params, mbatch, routing)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), st

            g0 = jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32), params)
            (grads, loss), stats_all = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = loss / m
            stats = jax.tree.map(lambda s: jnp.mean(s), stats_all)
        # grads are averaged over the batch via the loss mean; pjit
        # inserts the cross-replica psum automatically from the
        # sharding constraints.
        new_params, new_opt, metrics = adamw_update(
            sc.opt, grads, opt_state, params)
        return new_params, new_opt, loss, dict(stats, **metrics)

    return train_step


def train_shardings(sc: StepConfig, params_shape, opt_shape, batch_specs):
    dist = sc.dist
    pspec = step_pspecs(sc, params_shape)
    ospec = {"mu": step_pspecs(sc, opt_shape["mu"]),
             "nu": step_pspecs(sc, opt_shape["nu"]),
             "step": P()}
    in_shardings = (tree_named(dist, pspec), tree_named(dist, ospec),
                    tree_named(dist, batch_specs), None)
    out_shardings = (tree_named(dist, pspec), tree_named(dist, ospec),
                     None, None)
    return in_shardings, out_shardings


# ----------------------------------------------------------------------
# serve (decode) + prefill steps
# ----------------------------------------------------------------------


def make_serve_step(sc: StepConfig, *, greedy: bool = True):
    cfg, dist = sc.cfg, sc.dist

    def serve_step(params, tokens, pos, cache, routing):
        logits, new_cache, stats = LM.apply_lm(
            cfg, dist, params, tokens=tokens, pos=pos, cache=cache,
            routing=routing, mode="decode", algo=sc.algo_decode,
            moe_impl=sc.moe_impl)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache, stats

    return serve_step


def make_prefill_step(sc: StepConfig):
    cfg, dist = sc.cfg, sc.dist

    def prefill_step(params, batch, cache, routing):
        logits, new_cache, stats = LM.apply_lm(
            cfg, dist, params, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), frames=batch.get("frames"),
            cache=cache, routing=routing, mode="prefill",
            algo=sc.algo_train, moe_impl=sc.moe_impl, chunk=sc.attn_chunk)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache, stats

    return prefill_step


def serve_shardings(sc: StepConfig, params_shape, cache_specs_tree,
                    batch_size: int):
    dist = sc.dist
    pspec = step_pspecs(sc, params_shape, fsdp=False)
    tok_spec = P(dist.dp_axes) if (
        dist.mesh and batch_size % dist.dp_size == 0) else P()
    in_shardings = (
        tree_named(dist, pspec),
        _ns(dist, P(*tok_spec, None)),     # tokens [B, 1]
        _ns(dist, tok_spec),               # pos [B]
        tree_named(dist, cache_specs_tree),
        None,                              # routing tables (replicated)
    )
    out_shardings = (_ns(dist, tok_spec),
                     tree_named(dist, cache_specs_tree), None)
    return in_shardings, out_shardings


def serve_cache_pspecs(cfg: ModelConfig, dist: Dist,
                       long_context: bool = False):
    if cfg.family == "encdec":
        ax = dist.tp_axis
        s = P(None, dist.dp_axes, ax, None, None)
        return {"self_k": s, "self_v": s, "cross_k": s, "cross_v": s}
    return LM.cache_pspec(cfg, dist, long_context)


def sanitize_specs(spec_tree, shape_tree, dist: Dist):
    """Per-dim divisibility fallback for a PartitionSpec pytree against
    the matching ShapeDtypeStruct pytree (e.g. whisper's 8 KV heads on a
    16-way model axis fall back to replication)."""
    import numpy as np

    def ok(dim, axes):
        if axes is None or dist.mesh is None:
            return False
        if isinstance(axes, str):
            axes = (axes,)
        return dim % int(np.prod([dist.mesh.shape[a] for a in axes])) == 0

    def one(spec, aval):
        fixed = tuple(a if ok(d, a) else None
                      for d, a in zip(aval.shape, tuple(spec)))
        return P(*fixed)

    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda s: isinstance(s, P))
