"""Model/architecture configuration system.

One frozen dataclass describes every supported architecture family:
dense decoder LMs, GQA variants (qk-norm, sliding-window, local:global
interleave), MoE (routed + shared experts), SSM (mamba1), hybrid
(jamba-style mamba+attention+MoE interleave), and encoder-decoder
(whisper-style, stubbed frontend).

Configs register themselves in ``REGISTRY`` (``--arch <id>`` selects one).
``reduced()`` produces the CPU-smoke-test sized variant of the same
family, preserving every structural feature (pattern period, MoE top-k,
shared experts, qk-norm, ...) while shrinking widths/depths.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

REGISTRY: dict[str, "ModelConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention features ---
    qk_norm: bool = False
    nonparametric_norm: bool = False     # olmo: LN without scale/bias
    sliding_window: Optional[int] = None # SWA width where used
    local_global_period: int = 0         # gemma3: N local then 1 global
    rope_theta: float = 1e4
    max_seq_len: int = 131072

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    d_ff_expert: int = 0                 # per-expert hidden (0 -> d_ff)
    moe_period: int = 1                  # MoE FFN every k-th layer
    norm_topk_prob: bool = True          # softmax over selected k

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_period: int = 0                 # hybrid: 1 attn layer per period
    attn_offset: int = 0                 # position of attn layer in period

    # --- encoder-decoder (whisper-style) ---
    encoder_layers: int = 0
    encoder_frames: int = 0              # stubbed frame-embedding length

    # --- embeddings / IO ---
    input_mode: str = "tokens"           # tokens | embeddings (vlm stub)
    tie_embeddings: bool = False
    gated_mlp: bool = True               # SwiGLU (True) vs GELU MLP

    # --- which shape cells apply (DESIGN.md §7) ---
    supports_decode: bool = True
    supports_long_context: bool = False

    def __post_init__(self):
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec")
        if self.family in ("moe", "hybrid"):
            assert self.num_experts > 0 and self.num_experts_per_tok > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def expert_hidden(self) -> int:
        return self.d_ff_expert or self.d_ff

    @property
    def pattern_period(self) -> int:
        """Length of the repeating layer block (for scan-over-blocks)."""
        p = 1
        if self.local_global_period:
            p = self.local_global_period + 1
        if self.attn_period:
            p = max(p, self.attn_period)
        if self.moe_period > 1:
            p = _lcm(p, self.moe_period)
        assert self.num_layers % p == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by "
            f"pattern period {p}")
        return p

    def layer_kinds(self) -> list[tuple[str, str]]:
        """(mixer, ffn) kind for each layer inside one pattern period.

        mixer in {attn_full, attn_swa, mamba}; ffn in {dense, moe}.
        """
        kinds = []
        for i in range(self.pattern_period):
            if self.family in ("ssm", "hybrid"):
                if self.attn_period and i % self.attn_period == self.attn_offset:
                    mixer = "attn_full"
                else:
                    mixer = "mamba"
            elif self.local_global_period:
                # gemma3-style: local(SWA) x N then 1 global
                mixer = ("attn_full"
                         if (i + 1) % (self.local_global_period + 1) == 0
                         else "attn_swa")
            elif self.sliding_window:
                mixer = "attn_swa"
            else:
                mixer = "attn_full"
            if self.family == "ssm":
                ffn = "none"    # mamba1 block has no separate FFN
            elif self.is_moe and (i % self.moe_period == self.moe_period - 1
                                  if self.moe_period > 1 else True):
                ffn = "moe"
            else:
                ffn = "dense"
            kinds.append((mixer, ffn))
        return kinds

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters N (for 6*N*D model-FLOPs accounting)."""
        d, v = self.d_model, self.vocab_size
        emb = d * v * (1 if self.tie_embeddings else 2)
        per_period = 0
        for mixer, ffn in self.layer_kinds():
            if mixer.startswith("attn"):
                qkv = d * self.head_dim * (self.num_heads + 2 * self.num_kv_heads)
                per_period += qkv + self.num_heads * self.head_dim * d
            elif mixer == "mamba":
                di = self.ssm_expand * d
                dt_rank = max(d // 16, 1)
                per_period += (d * 2 * di + di * self.ssm_conv
                               + di * (dt_rank + 2 * self.ssm_state)
                               + dt_rank * di + di * self.ssm_state + di
                               + di * d)
            if ffn == "dense":
                n_mat = 3 if self.gated_mlp else 2
                per_period += n_mat * d * self.d_ff
            elif ffn == "moe":
                n_mat = 3 if self.gated_mlp else 2
                fe = self.expert_hidden
                per_period += d * self.num_experts          # router
                per_period += n_mat * d * fe * self.num_experts
                per_period += n_mat * d * fe * self.num_shared_experts
        blocks = self.num_layers // self.pattern_period
        total = emb + per_period * blocks
        if self.encoder_layers:
            enc_attn = d * self.head_dim * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * self.head_dim * d
            n_mat = 3 if self.gated_mlp else 2
            total += self.encoder_layers * (enc_attn + n_mat * d * self.d_ff)
            # decoder cross-attention
            total += self.num_layers * enc_attn
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        n_mat = 3 if self.gated_mlp else 2
        fe = self.expert_hidden
        moe_layers = sum(1 for _, f in self.layer_kinds() if f == "moe") \
            * (self.num_layers // self.pattern_period)
        all_experts = n_mat * self.d_model * fe * self.num_experts * moe_layers
        active_experts = n_mat * self.d_model * fe * self.num_experts_per_tok \
            * moe_layers
        return full - all_experts + active_experts

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test sized config of the same family (CPU-runnable)."""
        period = self.pattern_period
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=period if period > 1 else min(2, self.num_layers),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            d_ff_expert=32 if self.d_ff_expert else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 8),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            num_shared_experts=min(self.num_shared_experts, 2),
            ssm_state=min(self.ssm_state, 8),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=min(self.encoder_frames, 16) or 0,
            max_seq_len=512,
            sliding_window=16 if self.sliding_window else None,
        )


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration)
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)
