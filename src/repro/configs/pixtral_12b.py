"""pixtral-12b [vlm]: Pixtral-ViT frontend (stubbed) + Mistral-Nemo-style
decoder backbone. 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409; unverified]

The vision frontend is a STUB per the assignment: inputs are precomputed
patch embeddings of shape (B, S, d_model).
"""
from repro.configs.base import ModelConfig, register

PIXTRAL_12B = register(ModelConfig(
    name="pixtral-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    input_mode="embeddings",
    supports_long_context=False,   # full attention only
))
