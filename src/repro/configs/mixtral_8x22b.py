"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2 — 8 experts top-2, SWA. [arXiv:2401.04088; hf]

long_500k runs via the sliding-window (4096) rolling KV cache.
"""
from repro.configs.base import ModelConfig, register

MIXTRAL_8X22B = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    num_experts_per_tok=2,
    sliding_window=4096,
    rope_theta=1e6,
    supports_long_context=True,
))
