"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 — 4 shared + 60 routed top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 routed experts on a 16-way EP group pad to 64 replica slots via the
EPLB replication machinery (core/placement.slots_for_ratio).
"""
from repro.configs.base import ModelConfig, register

QWEN2_MOE_A2_7B = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    d_ff_expert=1408,
    vocab_size=151936,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    norm_topk_prob=False,        # qwen1.5-moe: softmax over all experts
    supports_long_context=False,
))
