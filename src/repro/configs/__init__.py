"""Architecture registry: importing this package registers all configs."""
from repro.configs.base import ModelConfig, REGISTRY, get_config, register
from repro.configs.shapes import SHAPES, ShapeCell, input_specs, cell_applicable

# assigned architectures (one module per arch id)
from repro.configs.pixtral_12b import PIXTRAL_12B
from repro.configs.olmo_1b import OLMO_1B
from repro.configs.deepseek_67b import DEEPSEEK_67B
from repro.configs.gemma3_12b import GEMMA3_12B
from repro.configs.qwen3_4b import QWEN3_4B
from repro.configs.whisper_base import WHISPER_BASE
from repro.configs.jamba_1_5_large import JAMBA_1_5_LARGE
from repro.configs.mixtral_8x22b import MIXTRAL_8X22B
from repro.configs.qwen2_moe_a2_7b import QWEN2_MOE_A2_7B
from repro.configs.falcon_mamba_7b import FALCON_MAMBA_7B

# the paper's own evaluation models
from repro.configs.paper_models import (
    QWEN3_30B_A3B, QWEN3_235B_A22B, DEEPSEEK_V3_671B)

ASSIGNED_ARCHS = [
    "pixtral-12b", "olmo-1b", "deepseek-67b", "gemma3-12b", "qwen3-4b",
    "whisper-base", "jamba-1.5-large-398b", "mixtral-8x22b",
    "qwen2-moe-a2.7b", "falcon-mamba-7b",
]

__all__ = [
    "ModelConfig", "REGISTRY", "get_config", "register",
    "SHAPES", "ShapeCell", "input_specs", "cell_applicable",
    "ASSIGNED_ARCHS",
]
