"""Assigned input-shape cells and ShapeDtypeStruct input specs.

LM transformer shapes are seq_len x global_batch.  ``decode_*`` /
``long_*`` lower ``serve_step`` (one new token against a KV cache of
seq_len), not ``train_step``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Whether this (arch x shape) cell runs (DESIGN.md §7)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: no sub-quadratic path at 500k"
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, no device allocation — feeds
    jax.jit(...).lower() in the dry-run.
    """
    b, s = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    bf16 = jnp.bfloat16
    specs: dict[str, jax.ShapeDtypeStruct] = {}

    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "embeddings":
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.encoder_layers:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), bf16)
    else:  # decode: one new token per request against a seq_len cache
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        specs["pos"] = jax.ShapeDtypeStruct((b,), i32)
        # KV / SSM caches are built by the model's cache_specs(); the
        # dry-run threads them as separate inputs.
    return specs
