"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16, i.e. MHA) d_ff=8192
vocab=50304 — non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from repro.configs.base import ModelConfig, register

OLMO_1B = register(ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    nonparametric_norm=True,
    tie_embeddings=True,
    supports_long_context=False,
))
