"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 —
encoder-decoder; conv frontend STUBBED (precomputed frame embeddings).
[arXiv:2212.04356; unverified]

Backbone-only per the assignment: input_specs() provides (B, 1500, 512)
frame embeddings for the encoder; the decoder consumes tokens.  Decode
shapes are lowered mechanically (32k self-attn cache) to prove the
sharding even though the real model caps at 448 decoder positions; noted
in DESIGN.md §7.
"""
from repro.configs.base import ModelConfig, register

WHISPER_BASE = register(ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,               # decoder layers
    encoder_layers=6,
    encoder_frames=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    gated_mlp=False,            # GELU MLP
    rope_theta=1e4,             # whisper uses learned/sinusoidal; RoPE stub
    supports_long_context=False,
))
