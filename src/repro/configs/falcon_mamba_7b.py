"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba1 arch. [arXiv:2410.05355; unverified]

METRO is inapplicable (no MoE, no attention); included per the
assignment and noted in DESIGN.md §Arch-applicability.  long_500k runs
(O(1) recurrent state).
"""
from repro.configs.base import ModelConfig, register

FALCON_MAMBA_7B = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
    supports_long_context=True,
))
