"""The paper's own evaluation models (§VI-A), beyond the assigned 10.

  * Qwen3-30B-A3B   — real-system testbed (128 experts, top-8)
  * Qwen3-235B-A22B — simulator testbed (128 experts, top-8)
  * DeepSeek-V3-671B — simulator testbed (256 experts, top-8 + 1 shared)

DeepSeek-V3 uses MLA attention; we approximate with GQA (kv=16) since
MLA is orthogonal to the paper's contribution (expert routing), and note
the deviation here.  DS-V3's first-3-dense-layers detail is likewise
folded into an all-MoE stack.
"""
from repro.configs.base import ModelConfig, register

QWEN3_30B_A3B = register(ModelConfig(
    name="qwen3-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=6144,
    d_ff_expert=768,
    vocab_size=151936,
    qk_norm=True,
    num_experts=128,
    num_experts_per_tok=8,
    rope_theta=1e6,
    supports_long_context=False,
))

QWEN3_235B_A22B = register(ModelConfig(
    name="qwen3-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=12288,
    d_ff_expert=1536,
    vocab_size=151936,
    qk_norm=True,
    num_experts=128,
    num_experts_per_tok=8,
    rope_theta=1e6,
    supports_long_context=False,
))

DEEPSEEK_V3_671B = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=16,            # MLA approximated by GQA (see docstring)
    head_dim=128,
    d_ff=18432,
    d_ff_expert=2048,
    vocab_size=129280,
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    supports_long_context=False,
))
