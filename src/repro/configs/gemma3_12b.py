"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global attention interleave, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

long_500k runs: 5/6 of layers are sliding-window (1024) with rolling
caches; the global layers use the sequence-sharded KV path (DESIGN.md §7).
"""
from repro.configs.base import ModelConfig, register

GEMMA3_12B = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    qk_norm=True,
    local_global_period=5,      # 5 local then 1 global
    sliding_window=1024,
    rope_theta=1e6,
    tie_embeddings=True,
    supports_long_context=True,
))
