"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
every other layer. [arXiv:2403.19887; hf]

Pattern period 8: attention at offset 4, mamba elsewhere; MoE FFN on odd
layers (9 blocks of 8 layers). METRO applies to the MoE layers; the SSM
layers carry the 500k context (O(1) state).
"""
from repro.configs.base import ModelConfig, register

JAMBA_1_5_LARGE = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_period=2,
    ssm_state=16,
    attn_period=8,
    attn_offset=4,
    supports_long_context=True,
))
