"""EPLB-style expert replication + placement (host-side, numpy).

Implements the two-step scheme the paper uses as the fixed substrate for
*both* routers (§II-C, §VI-A "both METRO and EPLB routing algorithms use
EPLB's expert placement and replication"):

  1. *Replication*: replica counts proportional to historical expert load
     (greedy largest-average-reduction, as in deepseek-ai/EPLB).
  2. *Placement*: balanced packing of replicas onto devices so that the
     *expected* token load per device is balanced, assuming the
     token-balanced router splits each expert's tokens evenly across its
     replicas.

Placement runs host-side every rebalance window; its output tables are
step inputs to the jitted routers (they are data, not compile consts).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import Placement


def replicate_experts(loads: np.ndarray, num_slots: int) -> np.ndarray:
    """Greedy replica-count assignment (EPLB step 1).

    Gives every expert one replica, then repeatedly grants an extra
    replica to the expert with the largest per-replica load.  Returns
    counts[N] with counts.sum() == num_slots.
    """
    n = len(loads)
    if num_slots < n:
        raise ValueError(f"need >= {n} slots to host {n} experts, got {num_slots}")
    loads = np.asarray(loads, dtype=np.float64) + 1e-9  # break ties stably
    counts = np.ones(n, dtype=np.int64)
    for _ in range(num_slots - n):
        counts[np.argmax(loads / counts)] += 1
    return counts


def pack_replicas(
    loads: np.ndarray,
    counts: np.ndarray,
    num_devices: int,
    slots_per_device: int,
) -> np.ndarray:
    """Balanced packing of replicas onto devices (EPLB step 2).

    Sorts replicas by per-replica expected load (descending) and greedily
    places each on the least-loaded device that still has a free slot,
    avoiding co-locating two replicas of the same expert on one device
    when possible.  Returns replica_expert[R] in slot-major layout.
    """
    n = len(counts)
    per_replica_load = np.asarray(loads, dtype=np.float64) / np.maximum(counts, 1)
    replicas = []  # (expert, load)
    for e in range(n):
        replicas += [(e, per_replica_load[e])] * int(counts[e])
    replicas.sort(key=lambda t: (-t[1], t[0]))

    dev_load = np.zeros(num_devices, dtype=np.float64)
    dev_free = np.full(num_devices, slots_per_device, dtype=np.int64)
    dev_has = [set() for _ in range(num_devices)]
    assignment = [[] for _ in range(num_devices)]
    for e, load in replicas:
        # prefer devices not already hosting this expert
        order = np.lexsort((np.arange(num_devices), dev_load))
        pick = None
        for d in order:
            if dev_free[d] > 0 and e not in dev_has[d]:
                pick = int(d)
                break
        if pick is None:  # fall back: allow co-location
            for d in order:
                if dev_free[d] > 0:
                    pick = int(d)
                    break
        assert pick is not None, "ran out of slots"
        assignment[pick].append(e)
        dev_load[pick] += load
        dev_free[pick] -= 1
        dev_has[pick].add(e)

    replica_expert = np.concatenate(
        [np.asarray(a, dtype=np.int32) for a in assignment])
    assert replica_expert.shape == (num_devices * slots_per_device,)
    return replica_expert


def build_placement(
    num_experts: int,
    num_devices: int,
    slots_per_device: int,
    loads: np.ndarray | None = None,
) -> Placement:
    """End-to-end EPLB placement for one rebalance window."""
    R = num_devices * slots_per_device
    if loads is None:
        loads = np.ones(num_experts)
    loads = np.asarray(loads, dtype=np.float64)
    counts = replicate_experts(loads, R)
    replica_expert = pack_replicas(loads, counts, num_devices, slots_per_device)

    max_rep = int(counts.max())
    expert_slots = np.full((num_experts, max_rep), -1, dtype=np.int32)
    fill = np.zeros(num_experts, dtype=np.int64)
    for r, e in enumerate(replica_expert):
        expert_slots[e, fill[e]] = r
        fill[e] += 1
    placement = Placement(
        num_experts=num_experts,
        num_devices=num_devices,
        slots_per_device=slots_per_device,
        replica_expert=replica_expert.astype(np.int32),
        expert_slots=expert_slots,
        expert_num_replicas=counts.astype(np.int32),
        slot_device=(np.arange(R) // slots_per_device).astype(np.int32),
    )
    placement.validate()
    return placement


def aggregate_expert_loads(loads: list[np.ndarray]) -> np.ndarray:
    """Cluster-wide expert-load signal: sum the per-replica EWMAs.

    Each serving replica keeps its own expert-load EWMA (updated per
    step from the routing histograms it actually saw).  The cluster's
    shared EPLB placement must balance the *total* load every expert
    receives across the fleet, so the aggregation is a plain sum —
    replicas that served more tokens weigh in proportionally, and for a
    single replica the aggregate degenerates to that replica's own EWMA
    (the single-replica-cluster ≡ bare-engine determinism invariant).
    """
    assert loads, "need at least one replica's loads"
    out = np.zeros_like(np.asarray(loads[0], dtype=np.float64))
    for ld in loads:
        ld = np.asarray(ld, dtype=np.float64)
        assert ld.shape == out.shape, (ld.shape, out.shape)
        out += ld
    return out


def slots_for_ratio(num_experts: int, num_devices: int,
                    replication_ratio: float) -> int:
    """Slots per device for a target replication ratio, rounded up so the
    slot count is divisible by the EP group size (this is also how the
    framework absorbs expert counts not divisible by the mesh axis, e.g.
    qwen2-moe's 60 experts on a 16-way EP group)."""
    want = int(np.ceil(num_experts * replication_ratio))
    return int(np.ceil(want / num_devices))
