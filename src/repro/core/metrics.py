"""Routing-quality metrics and the memory-bound runtime model (§III-B).

The central quantity is *activated expert replicas per device*: in the
memory-bound regime per-device MoE runtime ~ activated_replicas *
expert_weight_bytes / HBM_bw (weight streaming dominates; activation
traffic is <0.6% at decode batches <= 1K, paper §III-B).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Placement, RoutingStats

_INT = jnp.int32


def activated_per_device(
    token_slots: jax.Array,        # any shape, physical slot per (token,k), -1 pad
    num_devices: int,
    slots_per_device: int,
) -> jax.Array:
    """Number of activated replica slots on each EP device (jit-friendly)."""
    flat = token_slots.reshape(-1)
    valid = flat >= 0
    r = num_devices * slots_per_device
    hits = jnp.zeros(r, _INT).at[jnp.where(valid, flat, 0)].add(
        valid.astype(_INT))
    active = (hits > 0).astype(_INT).reshape(num_devices, slots_per_device)
    return active.sum(axis=1)


def tokens_per_device(
    token_slots: jax.Array,
    num_devices: int,
    slots_per_device: int,
) -> jax.Array:
    flat = token_slots.reshape(-1)
    valid = flat >= 0
    r = num_devices * slots_per_device
    hits = jnp.zeros(r, _INT).at[jnp.where(valid, flat, 0)].add(
        valid.astype(_INT))
    return hits.reshape(num_devices, slots_per_device).sum(axis=1)


def routing_stats(
    token_slots: np.ndarray | jax.Array,
    placement: Placement,
) -> RoutingStats:
    g, s = placement.num_devices, placement.slots_per_device
    act = np.asarray(activated_per_device(jnp.asarray(token_slots), g, s))
    tok = np.asarray(tokens_per_device(jnp.asarray(token_slots), g, s))
    return RoutingStats(
        max_activated=int(act.max()),
        mean_activated=float(act.mean()),
        activated_per_device=act,
        max_tokens=int(tok.max()),
        mean_tokens=float(tok.mean()),
        tokens_per_device=tok,
    )


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for the runtime model and the simulator."""

    name: str
    peak_flops: float        # per chip, bf16
    hbm_bw: float            # bytes/s per chip
    link_bw: float           # bytes/s per ICI/NVLink link
    collective_launch: float # fixed latency per collective, seconds
    hbm_capacity: float      # bytes


TPU_V5E = HardwareSpec("tpu-v5e", 197e12, 819e9, 50e9, 1e-6, 16e9)
A100_40G = HardwareSpec("a100-40g", 312e12, 1555e9, 600e9 / 8, 20e-6, 40e9)
B200 = HardwareSpec("b200", 2250e12, 8000e9, 900e9 / 8, 20e-6, 192e9)


def moe_layer_runtime(
    activated_per_dev: np.ndarray,   # [G]
    tokens_per_dev: np.ndarray,      # [G]
    *,
    d_model: int,
    d_ff: int,
    bytes_per_param: float,
    hw: HardwareSpec,
    gated: bool = True,
) -> float:
    """Memory-bound-aware per-layer MoE FFN runtime model (paper §III-B +
    the proprietary simulator's roofline form): per device, runtime =
    max(weight+activation traffic / HBM_bw, flops / peak); the layer time
    is the *slowest* device (load imbalance model)."""
    n_mats = 3 if gated else 2
    w_bytes = n_mats * d_model * d_ff * bytes_per_param
    act_bytes = tokens_per_dev * d_model * 2 * 2.0   # in+out, bf16
    flops = tokens_per_dev * (2.0 * n_mats * d_model * d_ff)
    t_mem = (activated_per_dev * w_bytes + act_bytes) / hw.hbm_bw
    t_comp = flops / hw.peak_flops
    return float(np.max(np.maximum(t_mem, t_comp)))
