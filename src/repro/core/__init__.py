"""METRO core: expert placement, token routing, quality metrics, oracle.

The paper's contribution lives here:
  placement.py — EPLB replication + balanced packing (substrate)
  routing.py   — METRO greedy router + EPLB token-balanced baseline
  optimal.py   — exact MIN-EXP-ROUTING solver (binary search + matching)
  metrics.py   — activated-expert metrics + memory-bound runtime model
"""
from repro.core.types import Placement, RoutingStats
from repro.core.placement import (build_placement, slots_for_ratio,
                                  aggregate_expert_loads)
from repro.core.routing import (
    route, route_metro, route_eplb, route_single,
    metro_token_slots, topk_histogram, rank_within_expert,
)
from repro.core.optimal import solve_min_exp_routing, optimal_lambda
from repro.core.metrics import (
    activated_per_device, tokens_per_device, routing_stats,
    moe_layer_runtime, HardwareSpec, TPU_V5E, A100_40G, B200,
)

__all__ = [
    "Placement", "RoutingStats", "build_placement", "slots_for_ratio",
    "aggregate_expert_loads",
    "route", "route_metro", "route_eplb", "route_single",
    "metro_token_slots", "topk_histogram", "rank_within_expert",
    "solve_min_exp_routing", "optimal_lambda",
    "activated_per_device", "tokens_per_device", "routing_stats",
    "moe_layer_runtime", "HardwareSpec", "TPU_V5E", "A100_40G", "B200",
]
