"""Shared datatypes for expert placement and token routing.

Terminology (matches the paper, §IV-A):
  N logical experts, G EP ranks (devices in the EP group), R physical
  replica *slots* with R = G * S (S slots per device, slot-major layout:
  slot r lives on device r // S).  The binary matrix A[N, G] of the paper
  is represented sparsely by ``expert_slots`` below.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Placement:
    """Static expert->replica-slot placement for one rebalance window.

    All arrays are host numpy; they are passed into jitted step functions
    as device arrays (they change only at rebalance boundaries, which
    happen host-side, so they are step *inputs*, not compile-time consts).
    """

    num_experts: int            # N
    num_devices: int            # G (EP group size)
    slots_per_device: int       # S
    replica_expert: np.ndarray  # [R] int32, logical expert held by each slot
    expert_slots: np.ndarray    # [N, max_rep] int32, slot ids per expert, -1 pad
    expert_num_replicas: np.ndarray  # [N] int32
    slot_device: np.ndarray     # [R] int32 == arange(R) // S

    @property
    def num_slots(self) -> int:
        return self.num_devices * self.slots_per_device

    @property
    def max_replicas(self) -> int:
        return int(self.expert_slots.shape[1])

    @property
    def replication_ratio(self) -> float:
        return self.num_slots / self.num_experts

    def placement_matrix(self) -> np.ndarray:
        """Dense A[N, G] from the paper's formulation (for tests/oracle)."""
        A = np.zeros((self.num_experts, self.num_devices), dtype=np.int32)
        for r, e in enumerate(self.replica_expert):
            A[int(e), r // self.slots_per_device] = 1
        return A

    def validate(self) -> None:
        R = self.num_slots
        assert self.replica_expert.shape == (R,)
        assert self.replica_expert.min() >= 0
        assert self.replica_expert.max() < self.num_experts
        # every logical expert must be hosted somewhere (no token drops)
        assert len(np.unique(self.replica_expert)) == self.num_experts
        for e in range(self.num_experts):
            slots = self.expert_slots[e]
            valid = slots[slots >= 0]
            assert len(valid) == self.expert_num_replicas[e]
            assert sorted(valid.tolist()) == sorted(
                np.nonzero(self.replica_expert == e)[0].tolist())


@dataclasses.dataclass(frozen=True)
class RoutingStats:
    """Per-EP-group routing quality metrics (paper Figs. 5d, 8)."""

    max_activated: int          # lambda: max activated replicas per device
    mean_activated: float
    activated_per_device: np.ndarray  # [G]
    max_tokens: int             # token-balance view (what EPLB optimizes)
    mean_tokens: float
    tokens_per_device: np.ndarray     # [G]
