"""Optimal MIN-EXP-ROUTING solver (paper §IV-B) — offline oracle.

Binary search on lambda; each candidate tested for feasibility with a
capacity-constrained bipartite matching (experts -> devices, device
capacity lambda) solved by Dinic's max-flow — the same construction as
the paper's CPU implementation.  Host-side numpy/python only: the paper
itself shows this is too slow for the datapath (31-104% of FFN runtime);
we keep it as the routing-quality oracle for Fig. 8 and the tests.
"""
from __future__ import annotations

from collections import deque

import numpy as np


class _Dinic:
    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[int] = []
        self.head: list[list[int]] = [[] for _ in range(n)]

    def add_edge(self, u: int, v: int, c: int) -> int:
        eid = len(self.to)
        self.head[u].append(eid)
        self.to.append(v)
        self.cap.append(c)
        self.head[v].append(eid + 1)
        self.to.append(u)
        self.cap.append(0)
        return eid

    def max_flow(self, s: int, t: int) -> int:
        flow = 0
        while True:
            level = [-1] * self.n
            level[s] = 0
            q = deque([s])
            while q:
                u = q.popleft()
                for eid in self.head[u]:
                    v = self.to[eid]
                    if self.cap[eid] > 0 and level[v] < 0:
                        level[v] = level[u] + 1
                        q.append(v)
            if level[t] < 0:
                return flow
            it = [0] * self.n

            def dfs(u: int, f: int) -> int:
                if u == t:
                    return f
                while it[u] < len(self.head[u]):
                    eid = self.head[u][it[u]]
                    v = self.to[eid]
                    if self.cap[eid] > 0 and level[v] == level[u] + 1:
                        d = dfs(v, min(f, self.cap[eid]))
                        if d > 0:
                            self.cap[eid] -= d
                            self.cap[eid ^ 1] += d
                            return d
                    it[u] += 1
                return 0

            while True:
                f = dfs(s, 1 << 30)
                if f == 0:
                    break
                flow += f


def _feasible(active: np.ndarray, A: np.ndarray, lam: int):
    """Matching feasibility for candidate lambda. Returns (ok, assignment)
    where assignment[i] = device for active expert i (or -1)."""
    n, g = A.shape
    act = np.nonzero(active)[0]
    m = len(act)
    if m == 0:
        return True, np.full(n, -1, dtype=np.int64)
    s, t = m + g, m + g + 1
    din = _Dinic(m + g + 2)
    expert_edges: dict[tuple[int, int], int] = {}
    for li, e in enumerate(act):
        din.add_edge(s, li, 1)
        for d in np.nonzero(A[e])[0]:
            expert_edges[(li, int(d))] = din.add_edge(li, m + int(d), 1)
    for d in range(g):
        din.add_edge(m + d, t, lam)
    ok = din.max_flow(s, t) == m
    assignment = np.full(n, -1, dtype=np.int64)
    if ok:
        for (li, d), eid in expert_edges.items():
            if din.cap[eid] == 0:  # saturated forward edge => matched
                assignment[act[li]] = d
    return ok, assignment


def solve_min_exp_routing(token_counts: np.ndarray, A: np.ndarray):
    """Returns (lambda_opt, assignment[N] of device ids, -1 for inactive).

    token_counts: [N] tokens per expert; A: [N, G] placement matrix.
    """
    token_counts = np.asarray(token_counts)
    A = np.asarray(A)
    active = token_counts > 0
    m = int(active.sum())
    if m == 0:
        return 0, np.full(A.shape[0], -1, dtype=np.int64)
    g = A.shape[1]
    lo, hi = int(np.ceil(m / g)), m
    best = None
    while lo < hi:
        mid = (lo + hi) // 2
        ok, assignment = _feasible(active, A, mid)
        if ok:
            hi = mid
            best = assignment
        else:
            lo = mid + 1
    if best is None:
        ok, best = _feasible(active, A, lo)
        assert ok, "lambda = num active experts must always be feasible"
    return lo, best


def optimal_lambda(token_counts: np.ndarray, A: np.ndarray) -> int:
    return solve_min_exp_routing(token_counts, A)[0]
