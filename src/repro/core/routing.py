"""Token-routing algorithms (the paper's core contribution), in JAX.

All routers answer the same question: given the per-(token, k) top-k
expert choices for a batch, *which physical replica slot* serves each
(token, k) pair?  (This is "token routing" in the paper's sense — replica
selection, not top-k selection.)

  * :func:`route_metro`   — the paper's greedy algorithm (Alg. 1): per
    expert with T[i] > 0, activate the replica on the candidate device
    with the fewest activated experts.  Per Lemma 1, *all* tokens of an
    expert go to that single replica.  Implemented as a `lax.scan` over
    experts (the TPU-native analogue of the paper's single-SM CUDA
    kernel; see kernels/metro_route.py for the Pallas version).
  * :func:`route_eplb`    — the token-balancing baseline used by
    vLLM/SGLang EPLB: expert i's tokens are round-robined across its
    replicas so every replica gets an even share.
  * :func:`route_single`  — degenerate router for no-replication
    placements (slot 0 of each expert); also the "hypothetical ideal"
    lower bound of Fig. 4 when replication is 1.0x.

Everything here is shape-static and jit-friendly: placement tables are
device arrays (step inputs), token counts are data.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_INT = jnp.int32
_BIG = jnp.iinfo(jnp.int32).max


def topk_histogram(expert_ids: jax.Array, num_experts: int) -> jax.Array:
    """T[1..N] of the paper: tokens per logical expert for this batch.

    ``expert_ids`` is any-shaped int array of top-k selections (pad with
    -1 for invalid entries)."""
    flat = expert_ids.reshape(-1)
    valid = flat >= 0
    return jnp.zeros(num_experts, _INT).at[
        jnp.where(valid, flat, 0)
    ].add(valid.astype(_INT))


def rank_within_expert(expert_ids: jax.Array) -> jax.Array:
    """Rank of each (token, k) pair among pairs that picked the same
    expert, in flat position order.  O(B log B) via stable sort; used by
    the EPLB round-robin router."""
    flat = expert_ids.reshape(-1)
    b = flat.shape[0]
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(b, dtype=_INT) - seg_start.astype(_INT)
    rank = jnp.zeros(b, _INT).at[order].set(rank_sorted)
    return rank.reshape(expert_ids.shape)


@partial(jax.jit, static_argnames=("num_devices", "slots_per_device"))
def route_metro(
    token_counts: jax.Array,      # [N] int, T[1..N]
    expert_slots: jax.Array,      # [N, max_rep] int, -1 padded
    *,
    num_devices: int,
    slots_per_device: int,
) -> jax.Array:
    """METRO greedy (paper Alg. 1). Returns expert_slot[N]: the single
    replica slot activated for each expert (-1 if the expert has no
    tokens this batch).

    Experts are processed in descending token count order — the
    activated-expert objective (lambda) is order-invariant for the greedy,
    but heavy-first gives better *secondary* token balance among devices
    with equal activation counts, which we use as the tie-break exactly so
    the router degrades gracefully toward token balance when activation
    counts tie (beyond-paper refinement; the paper's lock ordering is
    arbitrary thread order).
    """
    n = token_counts.shape[0]
    order = jnp.argsort(-token_counts, stable=True)

    def step(carry, i):
        act_load, tok_load = carry                      # [G], [G]
        t_i = token_counts[i]
        slots = expert_slots[i]                          # [max_rep]
        valid = slots >= 0
        devs = jnp.where(valid, slots // slots_per_device, 0)
        # lexicographic argmin over (activated, tokens, device id),
        # masked to valid candidate replicas:
        act = jnp.where(valid, act_load[devs], _BIG)
        best_act = jnp.min(act)
        tie1 = act == best_act
        tok = jnp.where(tie1, tok_load[devs], _BIG)
        best_tok = jnp.min(tok)
        tie2 = tie1 & (tok == best_tok)
        dev_key = jnp.where(tie2, devs, _BIG)
        j = jnp.argmin(dev_key)
        slot = slots[j]
        dev = devs[j]
        take = t_i > 0
        act_load = act_load.at[dev].add(jnp.where(take, 1, 0))
        tok_load = tok_load.at[dev].add(jnp.where(take, t_i, 0))
        return (act_load, tok_load), jnp.where(take, slot, -1)

    init = (jnp.zeros(num_devices, _INT), jnp.zeros(num_devices, _INT))
    (_, _), picked = jax.lax.scan(step, init, order)
    # scatter back from processing order to expert index
    expert_slot = jnp.zeros(n, _INT).at[order].set(picked)
    return expert_slot


def metro_token_slots(
    expert_ids: jax.Array,        # [..., k] int, -1 pad
    expert_slot: jax.Array,       # [N] from route_metro
) -> jax.Array:
    """Per-(token, k) slot under METRO (Lemma 1: all tokens of an expert
    share its one activated replica)."""
    safe = jnp.maximum(expert_ids, 0)
    slots = expert_slot[safe]
    return jnp.where(expert_ids >= 0, slots, -1)


def route_eplb(
    expert_ids: jax.Array,        # [..., k] int, -1 pad
    expert_slots: jax.Array,      # [N, max_rep]
    expert_num_replicas: jax.Array,  # [N]
) -> jax.Array:
    """EPLB token-balanced baseline: round-robin each expert's tokens
    across its replicas (the vLLM/SGLang implementation the paper
    compares against).  Returns per-(token, k) slot ids."""
    ranks = rank_within_expert(expert_ids)
    safe = jnp.maximum(expert_ids, 0)
    n_rep = jnp.maximum(expert_num_replicas[safe], 1)
    j = ranks % n_rep
    slots = jnp.take_along_axis(
        expert_slots[safe], j[..., None].astype(_INT), axis=-1)[..., 0]
    return jnp.where(expert_ids >= 0, slots, -1)


def route_single(
    expert_ids: jax.Array,
    expert_slots: jax.Array,
) -> jax.Array:
    """Always use replica 0 — exact for 1.0x replication placements."""
    safe = jnp.maximum(expert_ids, 0)
    slots = expert_slots[safe, 0]
    return jnp.where(expert_ids >= 0, slots, -1)


def route(
    algo: str,
    expert_ids: jax.Array,
    token_counts: jax.Array,
    expert_slots: jax.Array,
    expert_num_replicas: jax.Array,
    *,
    num_devices: int,
    slots_per_device: int,
    use_pallas: bool = False,
) -> jax.Array:
    """Dispatch on routing algorithm name -> per-(token, k) slot ids."""
    if algo == "metro":
        if use_pallas:
            from repro.kernels import ops as kops
            expert_slot = kops.metro_route(
                token_counts, expert_slots,
                num_devices=num_devices, slots_per_device=slots_per_device)
        else:
            expert_slot = route_metro(
                token_counts, expert_slots,
                num_devices=num_devices, slots_per_device=slots_per_device)
        return metro_token_slots(expert_ids, expert_slot)
    if algo == "eplb":
        return route_eplb(expert_ids, expert_slots, expert_num_replicas)
    if algo == "single":
        return route_single(expert_ids, expert_slots)
    raise ValueError(f"unknown routing algo: {algo!r}")
