"""Pallas TPU kernels for the paper's compute hot spots.

  metro_route.py  — Alg. 1 greedy routing on the scalar core (SMEM
                    load counters; TPU analogue of the single-SM CUDA
                    kernel, §V)
  moe_ffn.py      — grouped expert FFN with activated-expert-only
                    weight-tile streaming (the memory-bound mechanism
                    METRO optimizes, §III-B): the two-pass
                    grouped_ffn_pallas and the one-pass
                    fused_expert_ffn_pallas megakernel (up→act→down,
                    hidden resident in VMEM, dead-tile DMA/FLOP skip)
  flash_decode.py — online-softmax decode attention over bf16/fp8 KV
                    caches (in-register dequant after the block DMA)

ops.py: jitted wrappers (interpret mode read per call from
REPRO_PALLAS_INTERPRET, default on for CPU; explicit interpret=
overrides).  ref.py: pure-numpy oracles the tests sweep against.
README.md here: impl matrix, VMEM sizing rule, dead-tile contract.
"""
