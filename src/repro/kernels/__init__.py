"""Pallas TPU kernels for the paper's compute hot spots.

  metro_route.py  — Alg. 1 greedy routing on the scalar core (SMEM
                    load counters; TPU analogue of the single-SM CUDA
                    kernel, §V)
  moe_ffn.py      — grouped expert FFN with activated-expert-only
                    weight-tile streaming (the memory-bound mechanism
                    METRO optimizes, §III-B)
  flash_decode.py — online-softmax decode attention over bf16/fp8 KV
                    caches (in-register dequant after the block DMA)

ops.py: jitted wrappers (interpret=True on CPU; set
REPRO_PALLAS_INTERPRET=0 on real TPU).  ref.py: pure-numpy oracles the
tests sweep against.
"""
