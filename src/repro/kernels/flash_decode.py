"""Pallas TPU kernel: single-token decode attention over a (possibly
fp8-quantized) KV cache.

This is the OTHER memory-bound hot spot of the paper's regime: decode
latency = weight streaming (moe_ffn kernel) + KV-cache streaming (this
kernel).  The cache is read block-by-block HBM->VMEM in its STORED
dtype and dequantized in registers — so an fp8 cache genuinely halves
the dominant HBM traffic (the claim of EXPERIMENTS §Perf cells 2-3,
which plain XLA only realizes if the convert fuses).

Grid: (batch, kv_head, seq_blocks) — the seq dimension is innermost and
sequential, carrying the online-softmax state (m, l, acc) in VMEM
scratch.  Blocks fully beyond the request's position are masked.

Layout per program: q (1,1,G,hd), k/v (1,1,Sb,hd), out (1,1,G,hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

_NEG = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, n_blocks: int, scale: float):
    b = pl.program_id(0)
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # [G, hd]
    # dequantize in-register: HBM traffic stays at the stored dtype
    k = k_ref[0, 0].astype(jnp.float32)                 # [Sb, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    offs = sb * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1)
    valid = offs <= pos_ref[b]
    s = jnp.where(valid, s, _NEG)                       # [G, Sb]

    m_prev = m_ref[...]                                 # [G, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                              # [G, Sb]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(sb == n_blocks - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_pallas(q, k_cache, v_cache, pos, *, block_s: int = 512,
                        interpret: bool = True):
    """q: [B, KV, G, hd]; k/v_cache: [B, KV, S, hd] (bf16 or fp8);
    pos: [B] int32 (positions > pos are masked). Returns [B, KV, G, hd]
    in q.dtype."""
    b, kv, g, hd = q.shape
    s = k_cache.shape[2]
    block_s = min(block_s, s)
    assert s % block_s == 0, (s, block_s)
    n_blocks = s // block_s
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_kernel, block_s=block_s,
                               n_blocks=n_blocks, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, kv, n_blocks),
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda i, j, sb, pos: (i, j, 0, 0)),
                pl.BlockSpec((1, 1, block_s, hd),
                             lambda i, j, sb, pos: (i, j, sb, 0)),
                pl.BlockSpec((1, 1, block_s, hd),
                             lambda i, j, sb, pos: (i, j, sb, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda i, j, sb, pos: (i, j, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(pos.astype(jnp.int32), q, k_cache, v_cache)


# ----------------------------------------------------------------------
# paged variant: KV lives in a shared page pool; the per-sequence page
# table is scalar-prefetched and drives the K/V BlockSpec index map, so
# each program DMAs exactly the physical page it needs — the kernel
# never sees (or pays HBM traffic for) another sequence's pages, and no
# dense [B, S] view is ever materialized.
# ----------------------------------------------------------------------


def _paged_kernel(pos_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page_size: int, n_pages: int,
                  scale: float):
    b = pl.program_id(0)
    pb = pl.program_id(2)

    @pl.when(pb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)              # [ps, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    offs = pb * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = (offs <= pos_ref[b]) & (pt_ref[b, pb] >= 0)
    s = jnp.where(valid, s, _NEG)                       # [G, ps]

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(pb == n_pages - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_prefill_kernel(start_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *, page_size: int,
                          n_pages: int, group: int, scale: float,
                          window: int):
    b = pl.program_id(0)
    pb = pl.program_id(2)

    @pl.when(pb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # [C*G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)              # [ps, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)
    cg = q.shape[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    offs = pb * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                   # [1, ps]
    # chunk-offset query window: row r of the q block is query
    # position start[b] + r // group
    qpos = start_ref[b] + jax.lax.broadcasted_iota(
        jnp.int32, (cg, 1), 0) // group                 # [C*G, 1]
    valid = (offs <= qpos) & (pt_ref[b, pb] >= 0)
    if window > 0:
        valid &= offs > qpos - window
    s = jnp.where(valid, s, _NEG)                       # [C*G, ps]

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(pb == n_pages - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_prefill_paged(q, k_pool, v_pool, start, page_table, *,
                        window: int = 0, interpret: bool = True):
    """Chunked-prefill flash attention over the paged KV pool.

    The multi-token sibling of :func:`flash_decode_paged`: one prefill
    *chunk* of C queries per sequence attends to everything already
    written to its pages (earlier chunks + this one — the engine
    scatters the chunk's K/V into the pool before calling), with a
    chunk-offset query window: the query at chunk row c sits at absolute
    position ``start[b] + c`` and masks positions beyond it (and, when
    ``window`` > 0, positions at or below ``start[b] + c - window`` —
    SWA layers store the full sequence in pages and mask at read time).

    q: [B, KV, C, G, hd]; k/v_pool: [num_pages, page_size, KV, hd] (bf16
    or fp8); start: [B] int32; page_table: [B, Pmax] int32 (-1 = hole).
    Returns [B, KV, C, G, hd] in q.dtype.

    Grid (batch, kv_head, logical_page): the page dimension is innermost
    and sequential, carrying the online-softmax state for all C*G query
    rows of the chunk in VMEM scratch; the K/V index map reads the
    prefetched page table, so address translation happens at DMA-issue
    time on the scalar core and activation memory is O(C), not
    O(max_len).
    """
    b, kv, c, g, hd = q.shape
    num_pages, ps, kv_p, _ = k_pool.shape
    assert kv_p == kv, (kv_p, kv)
    pmax = page_table.shape[1]
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(
        _paged_prefill_kernel, page_size=ps, n_pages=pmax, group=g,
        scale=scale, window=int(window or 0))
    qf = q.reshape(b, kv, c * g, hd)

    def kv_map(i, j, pb, start, pt):
        return (jnp.maximum(pt[i, pb], 0), 0, j, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kv, pmax),
            in_specs=[
                pl.BlockSpec((1, 1, c * g, hd),
                             lambda i, j, pb, start, pt: (i, j, 0, 0)),
                pl.BlockSpec((1, ps, 1, hd), kv_map),
                pl.BlockSpec((1, ps, 1, hd), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, c * g, hd),
                                   lambda i, j, pb, start, pt: (i, j, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((c * g, 1), jnp.float32),
                pltpu.VMEM((c * g, 1), jnp.float32),
                pltpu.VMEM((c * g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, c * g, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(start.astype(jnp.int32), page_table.astype(jnp.int32),
      qf, k_pool, v_pool)
    return out.reshape(b, kv, c, g, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged(q, k_pool, v_pool, pos, page_table, *,
                       interpret: bool = True):
    """Paged flash decode.  q: [B, KV, G, hd]; k/v_pool:
    [num_pages, page_size, KV, hd] (bf16 or fp8); pos: [B] int32;
    page_table: [B, Pmax] int32 physical page ids (-1 = hole; holes and
    positions > pos are masked).  Returns [B, KV, G, hd] in q.dtype.

    Grid (batch, kv_head, logical_page): the page dimension is innermost
    and sequential, carrying online-softmax state; the K/V index map
    reads the prefetched page table, i.e. the address translation
    happens at DMA-issue time on the scalar core.
    """
    b, kv, g, hd = q.shape
    num_pages, ps, kv_p, _ = k_pool.shape
    assert kv_p == kv, (kv_p, kv)
    pmax = page_table.shape[1]
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(_paged_kernel, page_size=ps, n_pages=pmax,
                               scale=scale)

    def kv_map(i, j, pb, pos, pt):
        return (jnp.maximum(pt[i, pb], 0), 0, j, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kv, pmax),
            in_specs=[
                pl.BlockSpec((1, 1, g, hd),
                             lambda i, j, pb, pos, pt: (i, j, 0, 0)),
                pl.BlockSpec((1, ps, 1, hd), kv_map),
                pl.BlockSpec((1, ps, 1, hd), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda i, j, pb, pos, pt: (i, j, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(pos.astype(jnp.int32), page_table.astype(jnp.int32),
      q, k_pool, v_pool)
