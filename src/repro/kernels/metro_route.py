"""Pallas TPU kernel for METRO's greedy routing (paper Alg. 1).

TPU adaptation of the paper's single-SM CUDA kernel (§V): the algorithm
is confined to one TensorCore's scalar unit with the per-device load
counters in SMEM — the direct analogue of the paper's SM-local shared
memory.  Locks are unnecessary: the loop is sequential (the paper itself
notes lock contention bounds useful concurrency below 64), and the
deterministic order means every device computes the identical routing
from the all-gathered inputs, so no routing table is ever exchanged.

Inputs (see ref.metro_route_ref for exact semantics):
  order        [N]    processing order (heavy-first, computed by ops.py)
  token_counts [N]    T[1..N]
  expert_slots [N, W] candidate replica slots per expert (-1 pad)
Output:
  expert_slot  [N]    chosen slot per expert (-1 if no tokens)
Scratch (SMEM): act[G], tok[G] per-device load counters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = jnp.iinfo(jnp.int32).max


def _kernel(order_ref, counts_ref, slots_ref, out_ref, act_ref, tok_ref,
            *, num_devices: int, slots_per_device: int, width: int):
    n = order_ref.shape[0]

    def init_dev(g, _):
        act_ref[g] = 0
        tok_ref[g] = 0
        return _

    jax.lax.fori_loop(0, num_devices, init_dev, None)

    def per_expert(i, carry):
        e = order_ref[i]
        t = counts_ref[e]

        # lexicographic argmin over candidates: (act, tok, dev), first hit
        def scan_cand(j, best):
            b_act, b_tok, b_dev, b_slot = best
            s = slots_ref[e, j]
            valid = s >= 0
            d = jnp.where(valid, s // slots_per_device, 0)
            a = jnp.where(valid, act_ref[d], _BIG)
            tk = jnp.where(valid, tok_ref[d], _BIG)
            better = (a < b_act) | ((a == b_act) & (tk < b_tok)) | \
                     ((a == b_act) & (tk == b_tok) & (d < b_dev))
            better = better & valid
            return (jnp.where(better, a, b_act),
                    jnp.where(better, tk, b_tok),
                    jnp.where(better, d, b_dev),
                    jnp.where(better, s, b_slot))

        best = jax.lax.fori_loop(
            0, width, scan_cand, (_BIG, _BIG, _BIG, jnp.int32(-1)))
        dev, slot = best[2], best[3]

        @pl.when(t > 0)
        def _assign():
            out_ref[e] = slot
            act_ref[dev] = act_ref[dev] + 1
            tok_ref[dev] = tok_ref[dev] + t

        @pl.when(t <= 0)
        def _skip():
            out_ref[e] = -1

        return carry

    jax.lax.fori_loop(0, n, per_expert, None)


@functools.partial(
    jax.jit,
    static_argnames=("num_devices", "slots_per_device", "interpret"))
def metro_route_pallas(token_counts, expert_slots, *, num_devices: int,
                       slots_per_device: int, interpret: bool = True):
    """Greedy routing on the TPU scalar core. Returns expert_slot[N]."""
    n, width = expert_slots.shape
    order = jnp.argsort(-token_counts, stable=True).astype(jnp.int32)
    kernel = functools.partial(
        _kernel, num_devices=num_devices,
        slots_per_device=slots_per_device, width=width)
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[
            pltpu.SMEM((num_devices,), jnp.int32),
            pltpu.SMEM((num_devices,), jnp.int32),
        ],
        interpret=interpret,
    )(order, token_counts.astype(jnp.int32),
      expert_slots.astype(jnp.int32))
