"""Pallas TPU kernels: grouped expert-FFN matmuls with activated-expert-
only weight streaming, plus the fused one-pass up→act→down megakernel.

This is the memory-traffic mechanism METRO optimizes (paper §III-B): in
the memory-bound regime the MoE layer's runtime is dominated by expert
weight loads HBM->VMEM.  Every kernel here indexes its weight BlockSpec
by the scalar-prefetched ``tile_group`` map, so a weight tile is DMA'd
iff some *live* token tile references that expert — non-activated
experts' weights are never touched, and dead tiles (``tile_group[i] ==
-1``: buffer tiles holding only padding rows) repeat the previous live
tile's block indices so Pallas skips their DMA entirely (a repeated
block index is never refetched) and ``pl.when`` skips their FLOPs.

Two kernels:

``grouped_ffn_pallas``  — one grouped matmul (one of the two passes of
    the classic expert FFN).  Grid ``(m_tiles, f_tiles, k_tiles)``, K
    innermost for accumulation.  Semantics == ref.grouped_matmul_ref on
    live tiles; dead tiles emit zeros.

``fused_expert_ffn_pallas`` — the whole expert FFN in ONE kernel:
    per resident token tile it streams the group's up-projection
    k-tiles into an fp32 VMEM accumulator, applies the silu/gelu gating
    *in VMEM*, then streams the down-projection k-tiles and accumulates
    the output.  The ``[tile_m, n_up*fe]`` hidden never touches HBM,
    and each activated expert's weights are loaded exactly once per
    resident token tile.  Grid ``(m_tiles, k_up_tiles + k_down_tiles)``
    — the second dimension enumerates the up phases then the down
    phases; scratch persists across phases of the same token tile.
    Semantics == ref.fused_expert_ffn_ref.

VMEM sizing rule (see kernels/README.md): the fused kernel keeps
``tile_m * n_up*fe`` fp32 hidden + ``tile_m * fe`` gated + ``tile_m *
d`` fp32 output accumulators resident, plus one ``tile_k_up x n_up*fe``
up-weight tile and one ``tile_k_dn x d`` down-weight tile — choose
``tile_m`` / ``tile_k_*`` so the sum stays under ~half of VMEM
(double-buffered DMA needs the rest).

The MoE layer guarantees tile alignment and the trailing-dead layout
(all fully-dead tiles follow the last live tile) via build_pair_buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


# ----------------------------------------------------------------------
# two-pass grouped matmul (one pass per call)
# ----------------------------------------------------------------------


def _kernel(tile_group, n_live, x_ref, w_ref, out_ref, acc_ref, *,
            k_tiles: int):
    i = pl.program_id(0)
    ki = pl.program_id(2)
    live = tile_group[i] >= 0

    @pl.when(live & (ki == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _mac():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(ki == k_tiles - 1)
    def _flush():
        out_ref[...] = jnp.where(live, acc_ref[...],
                                 0.0).astype(out_ref.dtype)


def _dma_row(i, nl):
    """Last live token-tile row for grid step ``i``: dead tiles (which
    are trailing — build_pair_buffer's layout) repeat the previous live
    tile's block index, so Pallas never re-DMAs for them."""
    return jnp.maximum(jnp.minimum(i, nl[0] - 1), 0)


def _freeze(i, nl, live_idx, frozen_idx):
    """Block index for a possibly-dead grid row: live rows walk their
    own index, dead rows PARK on the last live tile's final index (the
    index must not change across a dead tile's grid steps, or Pallas
    would re-DMA — freezing the phase/k component is as load-bearing
    as freezing the group)."""
    return jnp.where(i < nl[0], live_idx, frozen_idx)


@functools.partial(
    jax.jit,
    static_argnames=("tile_m", "tile_k", "tile_f", "interpret"))
def grouped_ffn_pallas(x, w, tile_group, *, tile_m: int = 0,
                       tile_k: int = 512, tile_f: int = 512,
                       interpret: bool = True):
    """x: [C, d] (C = n_tiles * tile_m, sorted/tile-aligned); w: [S, d, f];
    tile_group: [n_tiles] int32, -1 = dead tile (skipped: no weight DMA,
    no FLOPs, zero output). Returns [C, f] in x.dtype."""
    c, d = x.shape
    s, _, f = w.shape
    n_tiles = tile_group.shape[0]
    tile_m = tile_m or c // n_tiles
    assert c == n_tiles * tile_m, (c, n_tiles, tile_m)
    tile_k = min(tile_k, d)
    tile_f = min(tile_f, f)
    assert d % tile_k == 0 and f % tile_f == 0, (d, tile_k, f, tile_f)
    k_tiles = d // tile_k

    tile_group = tile_group.astype(jnp.int32)
    n_live = jnp.sum(tile_group >= 0).astype(jnp.int32)[None]

    grid = (n_tiles, f // tile_f, k_tiles)
    kernel = functools.partial(_kernel, k_tiles=k_tiles)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (tile_m, tile_k),
                    lambda i, j, k, tg, nl: (
                        _dma_row(i, nl),
                        _freeze(i, nl, k, k_tiles - 1))),
                # weight tile selected by the token tile's expert — the
                # activated-expert-only streaming (dead tiles park on
                # the last live tile's FINAL (k, j) block: repeated
                # index, no DMA)
                pl.BlockSpec(
                    (1, tile_k, tile_f),
                    lambda i, j, k, tg, nl: (
                        jnp.maximum(tg[_dma_row(i, nl)], 0),
                        _freeze(i, nl, k, k_tiles - 1),
                        _freeze(i, nl, j, f // tile_f - 1))),
            ],
            out_specs=pl.BlockSpec((tile_m, tile_f),
                                   lambda i, j, k, tg, nl: (i, j)),
            scratch_shapes=[pltpu.VMEM((tile_m, tile_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((c, f), x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
    )(tile_group, n_live, x, w)


# ----------------------------------------------------------------------
# fused one-pass expert FFN: up → act → down, hidden stays in VMEM
# ----------------------------------------------------------------------


def _fused_kernel(tile_group, n_live, x_ref, wu_ref, wd_ref, out_ref,
                  h_ref, hg_ref, acc_ref, *, k_up: int, k_dn: int,
                  tile_k_dn: int, fe: int, gated: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)
    live = tile_group[i] >= 0

    @pl.when(j == 0)
    def _zero():
        h_ref[...] = jnp.zeros_like(h_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- up phases: accumulate the hidden in fp32 VMEM --------------
    @pl.when(live & (j < k_up))
    def _up():
        h_ref[...] += jnp.dot(x_ref[...], wu_ref[0],
                              preferred_element_type=jnp.float32)

    # ---- gate in VMEM after the last up k-tile ----------------------
    @pl.when(live & (j == k_up - 1))
    def _gate():
        # cast the fp32 accumulator to the compute dtype BEFORE the
        # activation — the two-pass datapath gates on the dtype-cast
        # matmul output (ragged_dot accumulates f32 internally, then
        # casts), and matching it keeps fused serve token-identical
        h = h_ref[...].astype(hg_ref.dtype)
        if gated:
            g, u = h[:, :fe], h[:, fe:]
            act = jax.nn.silu(g) * u
        else:
            act = jax.nn.gelu(h)
        hg_ref[...] = act.astype(hg_ref.dtype)

    # ---- down phases: stream w_down, accumulate the output ----------
    @pl.when(live & (j >= k_up))
    def _down():
        kf = j - k_up
        off = pl.multiple_of(kf * tile_k_dn, tile_k_dn)
        hblk = hg_ref[:, pl.ds(off, tile_k_dn)]
        acc_ref[...] += jnp.dot(hblk, wd_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(j == k_up + k_dn - 1)
    def _flush():
        out_ref[...] = jnp.where(live, acc_ref[...],
                                 0.0).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("gated", "tile_m", "tile_k_up", "tile_k_dn",
                     "interpret"))
def fused_expert_ffn_pallas(x, w_up, w_down, tile_group, *, gated: bool,
                            tile_m: int = 0, tile_k_up: int = 512,
                            tile_k_dn: int = 512, interpret: bool = True):
    """One-pass expert FFN: out = act(x @ w_up[g]) @ w_down[g] per tile.

    x: [C, d] sorted/tile-aligned buffer (C = n_tiles * tile_m);
    w_up: [S, d, n_up*fe] (n_up = 2 when ``gated``: [gate | up] halves);
    w_down: [S, fe, d]; tile_group: [n_tiles] int32, -1 = dead tile.
    Returns [C, d] in x.dtype; dead tiles yield exact zeros.

    The hidden activation never leaves VMEM and each live tile streams
    its group's up+down weights exactly once (dead tiles: no DMA, no
    FLOPs — their block indices repeat the last live tile's).
    """
    c, d = x.shape
    s, _, f_up = w_up.shape
    _, fe, _ = w_down.shape
    n_up = 2 if gated else 1
    assert f_up == n_up * fe, (f_up, n_up, fe)
    n_tiles = tile_group.shape[0]
    tile_m = tile_m or c // n_tiles
    assert c == n_tiles * tile_m, (c, n_tiles, tile_m)
    tile_k_up = min(tile_k_up, d)
    tile_k_dn = min(tile_k_dn, fe)
    assert d % tile_k_up == 0 and fe % tile_k_dn == 0, \
        (d, tile_k_up, fe, tile_k_dn)
    k_up = d // tile_k_up
    k_dn = fe // tile_k_dn

    tile_group = tile_group.astype(jnp.int32)
    n_live = jnp.sum(tile_group >= 0).astype(jnp.int32)[None]

    grid = (n_tiles, k_up + k_dn)
    kernel = functools.partial(
        _fused_kernel, k_up=k_up, k_dn=k_dn, tile_k_dn=tile_k_dn, fe=fe,
        gated=gated)

    def _g(i, nl, tg):
        return jnp.maximum(tg[_dma_row(i, nl)], 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                # x k-tile: advances over the up phases, parks on the
                # last up index during the down phases (no refetch);
                # dead tiles park on the last live tile's final index
                pl.BlockSpec(
                    (tile_m, tile_k_up),
                    lambda i, j, tg, nl: (
                        _dma_row(i, nl),
                        _freeze(i, nl, jnp.minimum(j, k_up - 1),
                                k_up - 1))),
                # up-weight tile: advances over up phases, parks after
                pl.BlockSpec(
                    (1, tile_k_up, f_up),
                    lambda i, j, tg, nl: (
                        _g(i, nl, tg),
                        _freeze(i, nl, jnp.minimum(j, k_up - 1),
                                k_up - 1), 0)),
                # down-weight tile: parks on 0 during up phases (its
                # single prefetch is the tile the first down phase
                # needs), advances over the down phases; dead tiles
                # park on the final down index
                pl.BlockSpec(
                    (1, tile_k_dn, d),
                    lambda i, j, tg, nl: (
                        _g(i, nl, tg),
                        _freeze(i, nl, jnp.maximum(j - k_up, 0),
                                k_dn - 1), 0)),
            ],
            out_specs=pl.BlockSpec((tile_m, d),
                                   lambda i, j, tg, nl: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((tile_m, f_up), jnp.float32),   # hidden acc
                pltpu.VMEM((tile_m, fe), x.dtype),         # gated hidden
                pltpu.VMEM((tile_m, d), jnp.float32),      # output acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((c, d), x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(tile_group, n_live, x, w_up, w_down)


# ----------------------------------------------------------------------
# paged fused expert FFN: weights live in a frame pool, manual
# double-buffered DMA overlaps tile i's compute with tile i+1's fetch
# ----------------------------------------------------------------------


def _paged_kernel(tile_group, n_live, frame_map, x_ref, wu_hbm, wd_hbm,
                  out_ref, wu_buf, wd_buf, sem, *, fe: int, gated: bool):
    i = pl.program_id(0)
    n = pl.num_programs(0)
    live = tile_group[i] >= 0

    def _copies(idx, slot):
        f = frame_map[jnp.maximum(tile_group[idx], 0)]
        return (pltpu.make_async_copy(wu_hbm.at[f], wu_buf.at[slot],
                                      sem.at[slot, 0]),
                pltpu.make_async_copy(wd_hbm.at[f], wd_buf.at[slot],
                                      sem.at[slot, 1]))

    # warm start: the first tile's weights have no earlier grid step to
    # hide behind
    @pl.when((i == 0) & live)
    def _warm():
        for cp in _copies(0, 0):
            cp.start()

    # prefetch the NEXT live tile's frame into the other buffer slot
    # while this tile computes — the double-buffered overlap.  Dead
    # tiles issue nothing (manual DMA needs no index-parking trick).
    nxt = jnp.minimum(i + 1, n - 1)

    @pl.when((i + 1 < n) & (tile_group[nxt] >= 0))
    def _prefetch():
        for cp in _copies(nxt, (i + 1) % 2):
            cp.start()

    @pl.when(live)
    def _compute():
        slot = i % 2
        for cp in _copies(i, slot):
            cp.wait()
        h = jnp.dot(x_ref[...], wu_buf[slot],
                    preferred_element_type=jnp.float32)
        # cast before the activation: parity with the two-pass datapath
        # (and fused_expert_ffn_pallas), which gates on the dtype-cast
        # matmul output
        h = h.astype(out_ref.dtype)
        if gated:
            act = jax.nn.silu(h[:, :fe]) * h[:, fe:]
        else:
            act = jax.nn.gelu(h)
        y = jnp.dot(act.astype(out_ref.dtype), wd_buf[slot],
                    preferred_element_type=jnp.float32)
        out_ref[...] = y.astype(out_ref.dtype)

    @pl.when(~live)
    def _dead():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(
    jax.jit,
    static_argnames=("gated", "tile_m", "interpret"))
def fused_expert_ffn_paged_pallas(x, wu_pool, wd_pool, frame_map,
                                  tile_group, *, gated: bool,
                                  tile_m: int = 0,
                                  interpret: bool = True):
    """Fused expert FFN reading weights from a paged frame pool.

    ``wu_pool``: [F, d, n_up*fe] and ``wd_pool``: [F, fe, d] hold F
    weight *frames* (F >= number of distinct live groups); they stay in
    ``ANY`` memory space (HBM) and are never blocked by the pipeline.
    ``frame_map``: [S] int32 maps expert slot -> frame index, so the
    caller (serving/expert_pool.py) controls physical placement.
    ``tile_group``: [n_tiles] int32 slot per token tile, -1 = dead.

    Per live tile the kernel manually DMAs frame ``frame_map[group]``'s
    up+down weights into a 2-slot VMEM ring — tile i's copy is started
    during tile i-1's compute (double-buffered overlap), with a warm
    start for tile 0 — then runs up → act → down entirely in VMEM.

    DMA contract: exactly one up + one down copy per LIVE tile; dead
    tiles issue nothing (no index-parking — the copies are explicit
    ``pl.when``-guarded ``make_async_copy`` calls, so even an all-dead
    grid moves zero weight bytes, unlike the automatic pipeline which
    must prefetch a parked block).  Adjacent same-group tiles refetch
    (no revisit-skip in the manual path) — acceptable at the pool's
    page granularity; see kernels/README.md.

    Semantics == fused_expert_ffn_pallas(x, wu_pool[frame_map],
    wd_pool[frame_map], tile_group) == ref.fused_expert_ffn_ref.
    """
    c, d = x.shape
    _, _, f_up = wu_pool.shape
    _, fe, _ = wd_pool.shape
    n_up = 2 if gated else 1
    assert f_up == n_up * fe, (f_up, n_up, fe)
    n_tiles = tile_group.shape[0]
    tile_m = tile_m or c // n_tiles
    assert c == n_tiles * tile_m, (c, n_tiles, tile_m)

    tile_group = tile_group.astype(jnp.int32)
    n_live = jnp.sum(tile_group >= 0).astype(jnp.int32)[None]
    frame_map = frame_map.astype(jnp.int32)

    kernel = functools.partial(_paged_kernel, fe=fe, gated=gated)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(n_tiles,),
            in_specs=[
                pl.BlockSpec((tile_m, d), lambda i, tg, nl, fm: (i, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),   # up-weight pool
                pl.BlockSpec(memory_space=pltpu.ANY),   # down-weight pool
            ],
            out_specs=pl.BlockSpec((tile_m, d),
                                   lambda i, tg, nl, fm: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((2, d, f_up), x.dtype),   # up-weight ring
                pltpu.VMEM((2, fe, d), x.dtype),     # down-weight ring
                pltpu.SemaphoreType.DMA((2, 2)),     # per slot: up, down
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((c, d), x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(tile_group, n_live, frame_map, x, wu_pool, wd_pool)
