"""Pallas TPU kernel: grouped expert-FFN matmul with activated-expert-only
weight streaming.

This is the memory-traffic mechanism METRO optimizes (paper §III-B): in
the memory-bound regime the MoE layer's runtime is dominated by expert
weight loads HBM->VMEM.  The kernel's weight BlockSpec is indexed by the
scalar-prefetched ``tile_group`` map, so a weight tile is DMA'd iff some
token tile references that expert — non-activated experts' weights are
*never touched*.  Consecutive tiles of the same group reuse the resident
VMEM buffer (Pallas skips the DMA when the block index repeats, which
the sorted layout maximizes).

Semantics == ref.grouped_matmul_ref: rows of token-tile t are multiplied
by w[tile_group[t]].  The MoE layer guarantees tile alignment via
build_pair_buffer.

Grid: (m_tiles, f_tiles, k_tiles) — K innermost for accumulation.
Blocks: x (tm, tk) / w (1, tk, tf) / out (tm, tf), fp32 accumulator in
VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _kernel(tile_group, x_ref, w_ref, out_ref, acc_ref, *, k_tiles: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == k_tiles - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile_m", "tile_k", "tile_f", "interpret"))
def grouped_ffn_pallas(x, w, tile_group, *, tile_m: int = 0,
                       tile_k: int = 512, tile_f: int = 512,
                       interpret: bool = True):
    """x: [C, d] (C = n_tiles * tile_m, sorted/tile-aligned); w: [S, d, f];
    tile_group: [n_tiles] int32. Returns [C, f] in x.dtype."""
    c, d = x.shape
    s, _, f = w.shape
    n_tiles = tile_group.shape[0]
    tile_m = tile_m or c // n_tiles
    assert c == n_tiles * tile_m, (c, n_tiles, tile_m)
    tile_k = min(tile_k, d)
    tile_f = min(tile_f, f)
    assert d % tile_k == 0 and f % tile_f == 0, (d, tile_k, f, tile_f)
    k_tiles = d // tile_k

    grid = (n_tiles, f // tile_f, k_tiles)
    kernel = functools.partial(_kernel, k_tiles=k_tiles)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile_m, tile_k),
                             lambda i, j, k, tg: (i, k)),
                # weight tile selected by the token tile's expert — the
                # activated-expert-only streaming
                pl.BlockSpec((1, tile_k, tile_f),
                             lambda i, j, k, tg: (tg[i], k, j)),
            ],
            out_specs=pl.BlockSpec((tile_m, tile_f),
                                   lambda i, j, k, tg: (i, j)),
            scratch_shapes=[pltpu.VMEM((tile_m, tile_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((c, f), x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
    )(tile_group.astype(jnp.int32), x, w)
