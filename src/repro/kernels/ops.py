"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and should be set
False on real TPU via REPRO_PALLAS_INTERPRET=0.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.metro_route import metro_route_pallas
from repro.kernels.moe_ffn import grouped_ffn_pallas
from repro.kernels.flash_decode import flash_decode_pallas

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def metro_route(token_counts, expert_slots, *, num_devices: int,
                slots_per_device: int):
    return metro_route_pallas(
        token_counts, expert_slots, num_devices=num_devices,
        slots_per_device=slots_per_device, interpret=_INTERPRET)


def grouped_ffn_matmul(x, w, tile_group):
    return grouped_ffn_pallas(x, w, tile_group, interpret=_INTERPRET)


def flash_decode(q, k_cache, v_cache, pos, block_s: int = 512):
    return flash_decode_pallas(q, k_cache, v_cache, pos,
                               block_s=block_s, interpret=_INTERPRET)
