"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on
real TPU via ``REPRO_PALLAS_INTERPRET=0``.  The env var is read *per
call* (at trace time), so tests and TPU runs can flip modes in-process;
an explicit ``interpret=`` argument overrides the env var entirely.
Note the underlying kernels are jitted with ``interpret`` static —
flipping the mode between calls retraces, it does not silently reuse
the previous mode's compilation.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.metro_route import metro_route_pallas
from repro.kernels.moe_ffn import (fused_expert_ffn_paged_pallas,
                                   fused_expert_ffn_pallas,
                                   grouped_ffn_pallas)
from repro.kernels.flash_decode import flash_decode_pallas


def _interpret(override=None) -> bool:
    if override is not None:
        return bool(override)
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def metro_route(token_counts, expert_slots, *, num_devices: int,
                slots_per_device: int, interpret=None):
    return metro_route_pallas(
        token_counts, expert_slots, num_devices=num_devices,
        slots_per_device=slots_per_device, interpret=_interpret(interpret))


def grouped_ffn_matmul(x, w, tile_group, *, interpret=None):
    return grouped_ffn_pallas(x, w, tile_group,
                              interpret=_interpret(interpret))


def fused_expert_ffn(x, w_up, w_down, tile_group, *, gated: bool,
                     interpret=None):
    return fused_expert_ffn_pallas(x, w_up, w_down, tile_group,
                                   gated=gated,
                                   interpret=_interpret(interpret))


def fused_expert_ffn_paged(x, wu_pool, wd_pool, frame_map, tile_group, *,
                           gated: bool, interpret=None):
    return fused_expert_ffn_paged_pallas(x, wu_pool, wd_pool, frame_map,
                                         tile_group, gated=gated,
                                         interpret=_interpret(interpret))


def flash_decode(q, k_cache, v_cache, pos, block_s: int = 512,
                 interpret=None):
    return flash_decode_pallas(q, k_cache, v_cache, pos, block_s=block_s,
                               interpret=_interpret(interpret))
