"""Pure-jnp/numpy oracles for the Pallas kernels.

These define the exact semantics each kernel must reproduce; the kernel
tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import numpy as np


def metro_route_ref(token_counts: np.ndarray, expert_slots: np.ndarray,
                    *, num_devices: int, slots_per_device: int) -> np.ndarray:
    """Sequential greedy (paper Alg. 1 with heavy-first deterministic
    order and (activated, tokens, device-id) lexicographic tie-break —
    identical to core.routing.route_metro).  Returns expert_slot[N]."""
    n = len(token_counts)
    act = np.zeros(num_devices, np.int64)
    tok = np.zeros(num_devices, np.int64)
    out = np.full(n, -1, np.int64)
    order = np.argsort(-np.asarray(token_counts), kind="stable")
    for e in order:
        t = int(token_counts[e])
        if t <= 0:
            continue
        slots = expert_slots[e]
        best = None
        for s in slots:
            if s < 0:
                continue
            d = s // slots_per_device
            key = (act[d], tok[d], d, s)
            if best is None or key < best[0]:
                best = (key, int(s), int(d))
        assert best is not None
        _, s_star, d_star = best
        out[e] = s_star
        act[d_star] += 1
        tok[d_star] += t
    return out


def grouped_matmul_ref(x: np.ndarray, w: np.ndarray,
                       tile_group: np.ndarray) -> np.ndarray:
    """Tile-wise grouped matmul: rows of tile t use weights w[tile_group[t]].

    x: [C, d]; w: [S, d, f]; tile_group: [C // tile]."""
    c, d = x.shape
    n_tiles = len(tile_group)
    tile = c // n_tiles
    out = np.zeros((c, w.shape[2]), np.float32)
    xf = np.asarray(x, np.float32)
    wf = np.asarray(w, np.float32)
    for t in range(n_tiles):
        sl = slice(t * tile, (t + 1) * tile)
        out[sl] = xf[sl] @ wf[int(tile_group[t])]
    return out


def fused_expert_ffn_ref(x: np.ndarray, w_up: np.ndarray,
                         w_down: np.ndarray, tile_group: np.ndarray,
                         *, gated: bool) -> np.ndarray:
    """Oracle for the fused one-pass expert FFN megakernel.

    Per token tile t with g = tile_group[t]:
      h = x[t] @ w_up[g]                       # [tile, n_up*fe]
      a = silu(h[:, :fe]) * h[:, fe:]  (gated) | gelu(h)  (otherwise)
      out[t] = a @ w_down[g]                   # [tile, d]
    Dead tiles (g == -1) are exact zeros — no weights touched.

    x: [C, d]; w_up: [S, d, n_up*fe]; w_down: [S, fe, d];
    tile_group: [C // tile] (-1 = dead).  fp32 math throughout.
    """
    c, d = x.shape
    fe = w_down.shape[1]
    n_tiles = len(tile_group)
    tile = c // n_tiles
    xf = np.asarray(x, np.float32)
    uf = np.asarray(w_up, np.float32)
    df = np.asarray(w_down, np.float32)
    out = np.zeros((c, d), np.float32)
    for t in range(n_tiles):
        g = int(tile_group[t])
        if g < 0:
            continue
        sl = slice(t * tile, (t + 1) * tile)
        h = xf[sl] @ uf[g]
        if gated:
            gate, up = h[:, :fe], h[:, fe:]
            a = gate / (1.0 + np.exp(-gate)) * up          # silu
        else:                                              # tanh-gelu
            a = 0.5 * h * (1.0 + np.tanh(
                np.sqrt(2.0 / np.pi) * (h + 0.044715 * h ** 3)))
        out[sl] = a @ df[g]
    return out


def flash_prefill_paged_ref(q: np.ndarray, k_pool: np.ndarray,
                            v_pool: np.ndarray, start: np.ndarray,
                            page_table: np.ndarray,
                            window: int = 0) -> np.ndarray:
    """Oracle for the chunked-prefill paged-attention kernel.

    q: [B, KV, C, G, hd]; k/v_pool: [P, ps, KV, hd]; start: [B] absolute
    position of each row's first chunk query; page_table: [B, Pmax]
    (-1 = hole).  Query c attends positions (start+c-window, start+c]
    (all of [0, start+c] when window == 0) restricted to mapped pages.
    """
    b, kv, c, g, hd = q.shape
    _, ps, _, _ = k_pool.shape
    pmax = page_table.shape[1]
    s_len = pmax * ps
    qf = np.asarray(q, np.float32)
    out = np.zeros_like(qf)
    scale = 1.0 / np.sqrt(hd)
    spos = np.arange(s_len)
    for i in range(b):
        # gather this sequence's pages into a dense [S, KV, hd] view
        kd = np.zeros((s_len, kv, hd), np.float32)
        vd = np.zeros((s_len, kv, hd), np.float32)
        mapped = np.zeros(s_len, bool)
        for p in range(pmax):
            pg = int(page_table[i, p])
            if pg < 0:
                continue
            kd[p * ps:(p + 1) * ps] = k_pool[pg]
            vd[p * ps:(p + 1) * ps] = v_pool[pg]
            mapped[p * ps:(p + 1) * ps] = True
        for ci in range(c):
            qpos = int(start[i]) + ci
            mask = mapped & (spos <= qpos)
            if window:
                mask &= spos > qpos - window
            for j in range(kv):
                logits = qf[i, j, ci] @ kd[:, j].T * scale    # [G, S]
                logits = np.where(mask[None, :], logits, -1e30)
                logits -= logits.max(axis=-1, keepdims=True)
                p_ = np.exp(logits)
                p_ /= p_.sum(axis=-1, keepdims=True)
                out[i, j, ci] = p_ @ vd[:, j]
    return out


def flash_decode_ref(q: np.ndarray, k_cache: np.ndarray,
                     v_cache: np.ndarray, pos: np.ndarray) -> np.ndarray:
    """Oracle for the decode-attention kernel.

    q: [B, KV, G, hd]; caches [B, KV, S, hd]; positions > pos masked."""
    b, kv, g, hd = q.shape
    s = k_cache.shape[2]
    qf = np.asarray(q, np.float32)
    kf = np.asarray(k_cache, np.float32)
    vf = np.asarray(v_cache, np.float32)
    out = np.zeros_like(qf)
    scale = 1.0 / np.sqrt(hd)
    for i in range(b):
        mask = np.arange(s) <= pos[i]
        for j in range(kv):
            logits = qf[i, j] @ kf[i, j].T * scale        # [G, S]
            logits = np.where(mask[None, :], logits, -1e30)
            logits -= logits.max(axis=-1, keepdims=True)
            p = np.exp(logits)
            p /= p.sum(axis=-1, keepdims=True)
            out[i, j] = p @ vf[i, j]
    return out
