"""End-to-end driver: serve a small MoE model with batched requests.

Runs the full serving engine — continuous batching, chunked prefill +
decode co-deployment, METRO decode routing, periodic EPLB rebalancing
with physical weight reshuffling — on a reduced Qwen3-30B-A3B-family
config on CPU, then compares METRO vs EPLB routing on the identical
request stream.

    PYTHONPATH=src python examples/serve_moe.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import EngineConfig, ServingEngine
from repro.sharding.policy import make_dist


def build_engine(decode_algo: str):
    cfg = get_config("qwen3-30b-a3b").reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.5)
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = build_placement(cfg.num_experts, ep, spd)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert)
    ecfg = EngineConfig(max_batch=8, max_len=96, decode_algo=decode_algo,
                        rebalance_every=32)
    return cfg, ServingEngine(cfg, dist, params, ecfg)


def main():
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, 256, int(rng.integers(4, 24)))
               for _ in range(12)]

    for algo in ("eplb", "metro"):
        cfg, eng = build_engine(algo)
        for p in prompts:
            eng.submit(p, max_new_tokens=16)
        t0 = time.perf_counter()
        s = eng.run()
        wall = time.perf_counter() - t0
        print(f"[{algo:5s}] {s['requests']} requests in {wall:.1f}s | "
              f"TTFT {s['ttft_mean']*1e3:.0f}ms  "
              f"TPOT {s['tpot_mean']*1e3:.1f}ms  "
              f"throughput {s['total_token_throughput']:.1f} tok/s  "
              f"({s['decode_steps']} decode / {s['prefill_steps']} "
              f"prefill steps)")
    print("\n(identical generated tokens across algos — routing only "
          "moves compute; on TPU the decode-phase gain comes from fewer "
          "activated experts per chip)")


if __name__ == "__main__":
    main()
