"""End-to-end driver: serve a small MoE model under synthetic load.

Runs the full serving engine — continuous batching, batched wave
prefill + decode co-deployment, power-of-two decode bucketing, paged KV
cache, METRO decode routing, periodic EPLB rebalancing with physical
weight reshuffling — on a reduced Qwen3-30B-A3B-family config on CPU,
then compares METRO vs EPLB routing on the identical Poisson request
trace.

    PYTHONPATH=src python examples/serve_moe.py
"""
import time

import jax

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import (EngineConfig, ServingEngine, TrafficConfig,
                           generate_trace, replay_open_loop)
from repro.sharding.policy import make_dist


def build_engine(decode_algo: str):
    cfg = get_config("qwen3-30b-a3b").reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.5)
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = build_placement(cfg.num_experts, ep, spd)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert)
    ecfg = EngineConfig(max_batch=8, max_len=96, decode_algo=decode_algo,
                        rebalance_every=32, page_size=16)
    return cfg, ServingEngine(cfg, dist, params, ecfg)


def main():
    trace = None
    for algo in ("eplb", "metro"):
        cfg, eng = build_engine(algo)
        if trace is None:
            trace = generate_trace(TrafficConfig(
                num_requests=12, arrival_rate=300.0, seed=42,
                prompt_len_mean=10, prompt_len_max=24,
                output_len_mean=16, output_len_sigma=0.2,
                output_len_max=16, vocab_size=cfg.vocab_size))
        t0 = time.perf_counter()
        s = replay_open_loop(eng, trace, step_time=5e-3)
        wall = time.perf_counter() - t0
        print(f"[{algo:5s}] {s['requests']} requests in {wall:.1f}s | "
              f"TTFT p50 {s['ttft_p50']*1e3:.0f}ms p99 "
              f"{s['ttft_p99']*1e3:.0f}ms | "
              f"TPOT p50 {s['tpot_p50']*1e3:.1f}ms p99 "
              f"{s['tpot_p99']*1e3:.1f}ms | "
              f"throughput {s['total_token_throughput']:.1f} tok/s | "
              f"{s['decode_steps']} decode / {s['chunk_steps']} chunk "
              f"/ {s['mixed_steps']} mixed / {s['prefill_steps']} wave "
              f"steps | stalls {s['decode_stall_events']} | "
              f"{s['total_compiles']} compiles "
              f"({s['decode_compiles']} decode)")
    print("\n(identical generated tokens across algos — routing only "
          "moves compute; on TPU the decode-phase gain comes from fewer "
          "activated experts per chip)")


if __name__ == "__main__":
    main()
