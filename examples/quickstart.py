"""Quickstart: METRO routing in 60 seconds.

Builds an EPLB placement, routes a skewed decode batch with both the
token-balancing baseline and METRO, and shows the activated-expert gap
(the paper's central quantity), validated against the optimal solver.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (build_placement, optimal, route, routing_stats,
                        slots_for_ratio, topk_histogram)
from repro.sim import synth_topk_batch

NUM_EXPERTS, EP_RANKS, TOP_K, BATCH = 64, 8, 4, 48
REPLICATION = 1.5

rng = np.random.default_rng(0)

# 1. EPLB placement: replicate hot experts, pack onto EP ranks
loads = 1.0 / np.arange(1, NUM_EXPERTS + 1) ** 1.2
spd = slots_for_ratio(NUM_EXPERTS, EP_RANKS, REPLICATION)
placement = build_placement(NUM_EXPERTS, EP_RANKS, spd, loads=loads)
print(f"placement: {NUM_EXPERTS} experts -> {placement.num_slots} replica "
      f"slots on {EP_RANKS} EP ranks ({placement.replication_ratio:.2f}x)")

# 2. a skewed decode batch picks its top-k experts
ids = jnp.asarray(synth_topk_batch(rng, NUM_EXPERTS, BATCH, TOP_K,
                                   alpha=1.2))
hist = topk_histogram(ids, NUM_EXPERTS)

# 3. route with both algorithms
for algo in ("eplb", "metro"):
    slots = route(algo, ids, hist, jnp.asarray(placement.expert_slots),
                  jnp.asarray(placement.expert_num_replicas),
                  num_devices=EP_RANKS, slots_per_device=spd)
    st = routing_stats(slots, placement)
    print(f"{algo:6s}: max activated experts/rank = {st.max_activated:2d} "
          f"(mean {st.mean_activated:.1f}), max tokens/rank = "
          f"{st.max_tokens}")

# 4. how close is METRO to optimal?
lam_opt, _ = optimal.solve_min_exp_routing(
    np.asarray(hist), placement.placement_matrix())
print(f"optimal: max activated experts/rank = {lam_opt}")
print("\nIn the memory-bound decode regime, per-rank MoE latency is "
      "proportional to\nactivated experts — METRO minimizes exactly "
      "that (paper §III-B).")
