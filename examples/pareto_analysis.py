"""Decode throughput-latency Pareto analysis (paper §VI-C, Figs. 12/13).

Sweeps batch sizes x TP/EP mappings x replication ratios on the B200
hardware model and prints the Pareto frontier for METRO vs EPLB,
including the fixed-SLO throughput ratio (the paper's 1.98-4.11x
headline).

    PYTHONPATH=src python examples/pareto_analysis.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.core.metrics import B200
from repro.sim import ParallelismConfig, WorkloadConfig, simulate_decode_step


def pareto_frontier(points):
    pts = sorted(points, key=lambda p: p[1])
    out, best = [], -1.0
    for tput, tpot, tag in pts:
        if tput > best:
            out.append((tput, tpot, tag))
            best = tput
    return out


def main():
    cfg = get_config("qwen3-235b-a22b")
    chips = 8
    wl = WorkloadConfig(zipf_alpha=1.2, domains=4)
    ctx = 2048
    for ratio in (1.0, 1.5):
        print(f"\n=== replication {ratio}x ===")
        for algo in ("eplb", "metro"):
            pts = []
            for tp in (1, 2, 4, 8):
                ep = chips // tp
                par = ParallelismConfig(tp=tp, ep=ep)
                rng = np.random.default_rng(7)
                spd = slots_for_ratio(cfg.num_experts, ep, ratio)
                p = build_placement(
                    cfg.num_experts, ep, spd,
                    loads=1.0 / np.arange(1, cfg.num_experts + 1) ** 1.2)
                for b in (1024, 512, 256, 128, 64):
                    r = simulate_decode_step(cfg, B200, par, b, ctx,
                                             algo, p, wl, rng)
                    pts.append((b / r["step_s"], r["step_s"],
                                f"tp{tp}/ep{ep}/b{b}"))
            front = pareto_frontier(pts)
            print(f"  {algo}:")
            for tput, tpot, tag in front:
                print(f"    {tput:9.0f} tok/s @ TPOT {tpot*1e3:6.2f} ms "
                      f"({tag})")


if __name__ == "__main__":
    main()
