"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps.

Full substrate: synthetic data pipeline -> jitted train step (EPLB
token-balanced routing, grad accumulation, AdamW) -> atomic checkpoints
with resume.  Run twice to see checkpoint/restart continue seamlessly.

    PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.steps import StepConfig
from repro.sharding.policy import make_dist
from repro.core import slots_for_ratio
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_moe")
    args = ap.parse_args()

    # ~100M-param member of the qwen2-moe family (60 experts, 4 shared)
    cfg = dataclasses.replace(
        get_config("qwen2-moe-a2.7b"),
        name="qwen2-moe-100m",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=8,
        head_dim=32, d_ff=512, d_ff_expert=512, vocab_size=8192,
        num_experts=16, num_experts_per_tok=4, num_shared_experts=1,
        max_seq_len=512)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active)")

    ep = 4
    dist = make_dist(None, ep_size=ep,
                     slots_per_device=slots_for_ratio(cfg.num_experts,
                                                      ep, 1.0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                    global_batch=8)
    tc = TrainConfig(total_steps=args.steps, ckpt_every=50,
                     ckpt_dir=args.ckpt_dir, log_every=10)
    sc = StepConfig(cfg=cfg, dist=dist, remat=False, fsdp=False,
                    microbatches=2)
    _, _, hist = train(cfg, dist, dc, tc, sc=sc)
    losses = [h["loss"] for h in hist]
    if losses:
        print(f"\nloss: first10={np.mean(losses[:10]):.3f} "
              f"last10={np.mean(losses[-10:]):.3f}")


if __name__ == "__main__":
    main()
