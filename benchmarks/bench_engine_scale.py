"""Engine-scale benchmark: the serving layer under synthetic load.

Two experiments on a reduced MoE config (CPU-runnable; the schedule and
compile counts are exact even though wall-clock is not a TPU claim):

  1. *Compile discipline* — the same bursty trace through (a) the
     seed-style engine (fixed max_batch bucket, dense KV, one prefill
     call per request) and (b) the bucketed engine (power-of-two decode
     buckets, paged KV, batched wave prefill).  Reports per-bucket
     compile counts; the bucketed engine must trigger fewer total
     step-function compiles AND produce identical tokens.

  2. *METRO vs EPLB under Poisson load* — open-loop replay of one
     heavy-tailed trace with decode routing flipped, reporting p50/p99
     TTFT and TPOT and decode-token throughput (the paper's Fig. 9-10
     quantities, measured through the real engine instead of the
     simulator).

Run:  PYTHONPATH=src python benchmarks/bench_engine_scale.py [--fast]
or via the suite driver: python benchmarks/run.py --only engine
"""
import argparse
import time
from collections import Counter

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import (EngineConfig, ServingEngine, TrafficConfig,
                           generate_trace, replay_open_loop)
from repro.sharding.policy import make_dist


def build_engine(arch="qwen3-30b-a3b", **kw):
    cfg = get_config(arch).reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25) if cfg.is_moe else 1
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, ep, spd)
                 if cfg.is_moe else None)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert
                     if placement else None)
    ecfg = EngineConfig(**kw)
    return cfg, ServingEngine(cfg, dist, params, ecfg)


def _trace(cfg, n, seed=0, rate=200.0):
    return generate_trace(TrafficConfig(
        num_requests=n, arrival_rate=rate, seed=seed,
        prompt_len_mean=10, prompt_len_max=40,
        output_len_mean=8, output_len_sigma=0.3, output_len_max=12,
        tail_fraction=0.2, tail_scale=3.0,
        vocab_size=cfg.vocab_size))


# ----------------------------------------------------------------------
# experiment 1: compile discipline, seed-style vs bucketed
# ----------------------------------------------------------------------


def compile_comparison(n_requests=16, fast=False):
    n = 8 if fast else n_requests
    variants = {
        "seed_fixed": dict(bucket_mode="fixed", kv_layout="dense",
                           batch_prefill=False),
        "bucketed_paged": dict(bucket_mode="pow2", kv_layout="paged",
                               batch_prefill=True),
    }
    results, tokens, rows = {}, {}, []
    for name, kw in variants.items():
        cfg, eng = build_engine(max_batch=8, max_len=64,
                                rebalance_every=0, **kw)
        trace = _trace(cfg, n, seed=1)
        for req in trace:                       # burst: submit all up front
            eng.submit(req.prompt, req.max_new_tokens)
        t0 = time.perf_counter()
        s = eng.run()
        wall = time.perf_counter() - t0
        results[name] = s
        tokens[name] = {rid: tuple(r.generated)
                        for rid, r in eng.completed.items()}
        per_bucket = Counter(eng.slo.compile_events["decode"])
        rows.append((
            f"engine_scale_compiles_{name}",
            s["decode_step_mean_s"] * 1e6,
            f"total_compiles={s['total_compiles']};"
            f"decode_compiles={s['decode_compiles']};"
            f"prefill_compiles={s['prefill_compiles']};"
            f"decode_buckets={sorted(per_bucket)};"
            f"wall={wall:.1f}s"))
    # wave prefill routes over the whole batch (by design), so tokens can
    # drift vs one-request-at-a-time prefill in bf16; report agreement.
    # (pow2-vs-fixed decode bucketing alone is bit-exact — locked down in
    # tests/test_engine_scale.py.)
    a, bkt = tokens["seed_fixed"], tokens["bucketed_paged"]
    agree = sum(a[r] == bkt[r] for r in a) / max(len(a), 1)
    complete = len(a) == len(bkt) == n
    fewer = (results["bucketed_paged"]["total_compiles"]
             < results["seed_fixed"]["total_compiles"])
    rows.append(("engine_scale_compiles_check", 0.0,
                 f"all_complete={complete};token_agreement={agree:.2f};"
                 f"bucketed_fewer_compiles={fewer}"))
    return rows, complete, fewer


# ----------------------------------------------------------------------
# experiment 2: METRO vs EPLB under Poisson open-loop load
# ----------------------------------------------------------------------


def load_comparison(n_requests=24, fast=False):
    n = 10 if fast else n_requests
    rows = []
    tput = {}
    for algo in ("eplb", "metro"):
        cfg, eng = build_engine(max_batch=8, max_len=64,
                                decode_algo=algo, rebalance_every=32,
                                page_size=8)
        trace = _trace(cfg, n, seed=2, rate=300.0)
        t0 = time.perf_counter()
        s = replay_open_loop(eng, trace, step_time=5e-3)
        wall = time.perf_counter() - t0
        decode_tokens = sum(t.n_generated
                            for t in eng.slo.timings.values())
        decode_time = sum(sec for k, sec in eng.slo.step_latencies
                          if k == "decode")
        tput[algo] = decode_tokens / max(decode_time, 1e-9)
        rows.append((
            f"engine_scale_poisson_{algo}",
            s["decode_step_mean_s"] * 1e6,
            f"requests={s['requests']};"
            f"ttft_p50={s['ttft_p50'] * 1e3:.0f}ms;"
            f"ttft_p99={s['ttft_p99'] * 1e3:.0f}ms;"
            f"tpot_p50={s['tpot_p50'] * 1e3:.1f}ms;"
            f"tpot_p99={s['tpot_p99'] * 1e3:.1f}ms;"
            f"decode_tput={tput[algo]:.1f}tok/s;"
            f"preempt={s['preemptions']};"
            f"qdepth_max={s['queue_depth_max']};wall={wall:.1f}s"))
    rows.append(("engine_scale_poisson_ratio", 0.0,
                 f"metro_over_eplb_decode_tput="
                 f"{tput['metro'] / max(tput['eplb'], 1e-9):.3f}"))
    return rows


def run(fast: bool = False):
    rows, _, _ = compile_comparison(fast=fast)
    rows += load_comparison(fast=fast)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    rows, complete, fewer = compile_comparison(fast=args.fast)
    rows += load_comparison(fast=args.fast)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    assert complete, "bucketed engine dropped requests"
    assert fewer, "bucketed engine did not reduce compiles"
    print("# OK: all requests served, bucketed engine compiles fewer "
          "step functions")


if __name__ == "__main__":
    main()
