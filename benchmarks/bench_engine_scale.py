"""Engine-scale benchmark: the serving layer under synthetic load.

Two experiments on a reduced MoE config (CPU-runnable; the schedule and
compile counts are exact even though wall-clock is not a TPU claim):

  1. *Compile discipline* — the same bursty trace through (a) the
     seed-style engine (fixed max_batch bucket, dense KV, one prefill
     call per request) and (b) the bucketed engine (power-of-two decode
     buckets, paged KV, batched wave prefill).  Reports per-bucket
     compile counts; the bucketed engine must trigger fewer total
     step-function compiles AND produce identical tokens.

  2. *METRO vs EPLB under Poisson load* — open-loop replay of one
     heavy-tailed trace with decode routing flipped, reporting p50/p99
     TTFT and TPOT and decode-token throughput (the paper's Fig. 9-10
     quantities, measured through the real engine instead of the
     simulator).

  3. *Mixed prefill+decode steps* — a long prompt arrives while short
     requests are mid-decode; wave-monolith vs chunked vs fused mixed
     steps.  Chunking must bound the worst prefill-carrying call (and
     therefore the decode TPOT spike) by O(prefill_chunk) instead of
     O(max_len), and mixed steps must eliminate decode stalls entirely;
     also reports the O(max_len) -> O(chunk) prefill activation-memory
     bound.

Run:  PYTHONPATH=src python benchmarks/bench_engine_scale.py [--fast]
or via the suite driver: python benchmarks/run.py --only engine
"""
import argparse
import time
from collections import Counter

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import (EngineConfig, ServingEngine, TrafficConfig,
                           generate_trace, replay_open_loop)
from repro.sharding.policy import make_dist


def build_engine(arch="qwen3-30b-a3b", **kw):
    cfg = get_config(arch).reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25) if cfg.is_moe else 1
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, ep, spd)
                 if cfg.is_moe else None)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert
                     if placement else None)
    ecfg = EngineConfig(**kw)
    return cfg, ServingEngine(cfg, dist, params, ecfg)


def _trace(cfg, n, seed=0, rate=200.0):
    return generate_trace(TrafficConfig(
        num_requests=n, arrival_rate=rate, seed=seed,
        prompt_len_mean=10, prompt_len_max=40,
        output_len_mean=8, output_len_sigma=0.3, output_len_max=12,
        tail_fraction=0.2, tail_scale=3.0,
        vocab_size=cfg.vocab_size))


# ----------------------------------------------------------------------
# experiment 1: compile discipline, seed-style vs bucketed
# ----------------------------------------------------------------------


def compile_comparison(n_requests=16, fast=False):
    n = 8 if fast else n_requests
    variants = {
        "seed_fixed": dict(bucket_mode="fixed", kv_layout="dense",
                           batch_prefill=False),
        # wave mode pinned: the monolithic wave path is the program the
        # seed scheduler also runs, so token agreement is comparable;
        # chunked/mixed prefill gets its own experiment below.
        "bucketed_paged": dict(bucket_mode="pow2", kv_layout="paged",
                               batch_prefill=True, prefill_mode="wave"),
    }
    results, tokens, rows = {}, {}, []
    for name, kw in variants.items():
        cfg, eng = build_engine(max_batch=8, max_len=64,
                                rebalance_every=0, **kw)
        trace = _trace(cfg, n, seed=1)
        for req in trace:                       # burst: submit all up front
            eng.submit(req.prompt, req.max_new_tokens)
        t0 = time.perf_counter()
        s = eng.run()
        wall = time.perf_counter() - t0
        results[name] = s
        tokens[name] = {rid: tuple(r.generated)
                        for rid, r in eng.completed.items()}
        per_bucket = Counter(eng.slo.compile_events["decode"])
        rows.append((
            f"engine_scale_compiles_{name}",
            s["decode_step_mean_s"] * 1e6,
            f"total_compiles={s['total_compiles']};"
            f"decode_compiles={s['decode_compiles']};"
            f"prefill_compiles={s['prefill_compiles']};"
            f"decode_buckets={sorted(per_bucket)};"
            f"wall={wall:.1f}s"))
    # wave prefill routes over the whole batch (by design), so tokens can
    # drift vs one-request-at-a-time prefill in bf16; report agreement.
    # (pow2-vs-fixed decode bucketing alone is bit-exact — locked down in
    # tests/test_engine_scale.py.)
    a, bkt = tokens["seed_fixed"], tokens["bucketed_paged"]
    agree = sum(a[r] == bkt[r] for r in a) / max(len(a), 1)
    complete = len(a) == len(bkt) == n
    fewer = (results["bucketed_paged"]["total_compiles"]
             < results["seed_fixed"]["total_compiles"])
    rows.append(("engine_scale_compiles_check", 0.0,
                 f"all_complete={complete};token_agreement={agree:.2f};"
                 f"bucketed_fewer_compiles={fewer}"))
    return rows, complete, fewer


# ----------------------------------------------------------------------
# experiment 2: METRO vs EPLB under Poisson open-loop load
# ----------------------------------------------------------------------


def load_comparison(n_requests=24, fast=False):
    n = 10 if fast else n_requests
    rows = []
    tput = {}
    for algo in ("eplb", "metro"):
        cfg, eng = build_engine(max_batch=8, max_len=64,
                                decode_algo=algo, rebalance_every=32,
                                page_size=8)
        trace = _trace(cfg, n, seed=2, rate=300.0)
        t0 = time.perf_counter()
        s = replay_open_loop(eng, trace, step_time=5e-3)
        wall = time.perf_counter() - t0
        decode_tokens = sum(t.n_generated
                            for t in eng.slo.timings.values())
        decode_time = sum(sec for k, sec in eng.slo.step_latencies
                          if k == "decode")
        tput[algo] = decode_tokens / max(decode_time, 1e-9)
        rows.append((
            f"engine_scale_poisson_{algo}",
            s["decode_step_mean_s"] * 1e6,
            f"requests={s['requests']};"
            f"ttft_p50={s['ttft_p50'] * 1e3:.0f}ms;"
            f"ttft_p99={s['ttft_p99'] * 1e3:.0f}ms;"
            f"tpot_p50={s['tpot_p50'] * 1e3:.1f}ms;"
            f"tpot_p99={s['tpot_p99'] * 1e3:.1f}ms;"
            f"decode_tput={tput[algo]:.1f}tok/s;"
            f"preempt={s['preemptions']};"
            f"qdepth_max={s['queue_depth_max']};wall={wall:.1f}s"))
    rows.append(("engine_scale_poisson_ratio", 0.0,
                 f"metro_over_eplb_decode_tput="
                 f"{tput['metro'] / max(tput['eplb'], 1e-9):.3f}"))
    return rows


# ----------------------------------------------------------------------
# experiment 3: decode TPOT under a long-prompt prefill — wave monolith
# vs chunked prefill vs fused mixed steps (sarathi piggybacking)
# ----------------------------------------------------------------------


def _wave_scratch_bytes(cfg, b, l):
    """Bytes of bf16 K/V scratch a wave-prefill call holds for ALL
    attention layers simultaneously (the init_wave_cache pytree)."""
    from repro.models.layers import attn_dims
    kinds = cfg.layer_kinds()
    n_blocks = cfg.num_layers // len(kinds)
    dims = attn_dims(cfg)
    n_attn = sum(1 for m, _ in kinds if m.startswith("attn"))
    return n_attn * n_blocks * b * dims.kv * l * dims.head_dim * 2 * 2


def mixed_prefill_comparison(fast=False):
    """A long prompt arrives while short requests are mid-decode.

    wave    — the whole prompt prefills in one monolithic call; every
              decode row stalls behind it (TPOT spike ~ O(max_len)).
    chunked — prefill advances one prefill_chunk per iteration; decode
              runs between chunks (stall bounded by one chunk).
    mixed   — the chunk and the decode tokens share ONE fused call; no
              stall is ever recorded.

    Also reports the prefill activation-memory bound: the wave scratch
    is O(max_len) across all layers at once, the chunk path touches
    O(prefill_chunk) per call.
    """
    max_len, chunk = 256, 32
    n_short, gen = (3, 16) if fast else (4, 40)
    long_len = 120 if fast else 200
    variants = {
        "wave": dict(prefill_mode="wave"),
        "chunked": dict(prefill_mode="chunked", mixed_steps=False),
        "mixed": dict(prefill_mode="chunked", mixed_steps=True),
    }
    rows, worst, met = [], {}, {}
    for name, kw in variants.items():
        cfg, eng = build_engine(max_batch=8, max_len=max_len,
                                rebalance_every=0, prefill_chunk=chunk,
                                page_size=16, bucket_compile_grace=0,
                                **kw)
        rng = np.random.default_rng(5)

        def phase():
            for _ in range(n_short):
                eng.submit(rng.integers(0, cfg.vocab_size, 12), gen)
            for _ in range(6):              # shorts are live decoders
                eng.step()
            eng.submit(rng.integers(0, cfg.vocab_size, long_len), 8)
            first = eng._next_rid - n_short - 1
            eng.run()
            return list(range(first, eng._next_rid))

        phase()                             # warmup: compiles every
        m_steps = len(eng.slo.step_latencies)      # signature this shape
        m_stalls = len(eng.slo.stalls)             # profile will touch
        rids = phase()                      # measured (steady-state)
        steps = eng.slo.step_latencies[m_steps:]
        stalls = [s for _, s in eng.slo.stalls[m_stalls:]]
        tpots = np.asarray([eng.slo.timings[r].tpot for r in rids
                            if eng.slo.timings[r].n_generated > 1])
        prefill_calls = [sec for k, sec in steps
                         if k in ("prefill", "chunk", "mixed")]
        worst[name] = max(prefill_calls, default=0.0)
        met[name] = {"stall_max": max(stalls, default=0.0),
                     "stall_p50": float(np.median(stalls)) if stalls
                     else 0.0,
                     "stall_events": len(stalls)}
        rows.append((
            f"engine_scale_mixed_{name}",
            float(np.percentile(tpots, 99)) * 1e6,
            f"requests={len(rids)};"
            f"tpot_p50={np.percentile(tpots, 50) * 1e3:.1f}ms;"
            f"tpot_p99={np.percentile(tpots, 99) * 1e3:.1f}ms;"
            f"stall_events={len(stalls)};"
            f"stall_total={sum(stalls) * 1e3:.0f}ms;"
            f"stall_max={max(stalls, default=0) * 1e3:.0f}ms;"
            f"worst_prefill_call={worst[name] * 1e3:.0f}ms;"
            f"prefill_calls={len(prefill_calls)}"))
    # cfg from the variants loop (same arch for every variant).
    # wave_scratch: the PERSISTENT all-layer init_wave_cache pytree a
    # monolithic prefill call holds for its whole duration (O(max_len)
    # per layer, all layers at once) — chunked prefill eliminates it
    # entirely and keeps only O(chunk) K/V per call.  Honesty note: the
    # jnp reference chunk path still materializes a TRANSIENT
    # O(max_len) gathered page view per attention layer while that
    # layer runs; the true end-to-end O(chunk) footprint is what the
    # flash_prefill_paged Pallas kernel delivers by streaming pages
    # (wiring it into the engine is a ROADMAP item).
    wave_b = _wave_scratch_bytes(cfg, 1, 256)
    chunk_b = _wave_scratch_bytes(cfg, 1, chunk)
    rows.append((
        "engine_scale_mixed_memory", 0.0,
        f"wave_persistent_scratch_bytes={wave_b};"
        f"chunk_kv_bytes_per_call={chunk_b};"
        f"persistent_bound_ratio={wave_b / max(chunk_b, 1):.1f}x;"
        f"note=jnp_ref_chunk_path_still_gathers_O(max_len)_transient_"
        f"per_layer,kernel_path_streams_O(chunk)"))
    # the gating claim is the deterministic stall STRUCTURE (wall-clock
    # on tiny CPU models is dispatch-overhead noise; the timing columns
    # above are the observables): the wave monolith stalls decode ONCE
    # for the whole prompt, chunking splits that into several
    # chunk-bounded stalls, and fused mixed steps stall decode never
    bounded = (met["wave"]["stall_events"] == 1
               and met["chunked"]["stall_events"] > 1
               and met["mixed"]["stall_events"] == 0)
    rows.append(("engine_scale_mixed_check", 0.0,
                 f"chunk_stall_bounded={bounded};"
                 f"wave_stall_max={met['wave']['stall_max'] * 1e3:.0f}ms;"
                 f"chunk_stall_p50="
                 f"{met['chunked']['stall_p50'] * 1e3:.0f}ms;"
                 f"chunk_stall_max="
                 f"{met['chunked']['stall_max'] * 1e3:.0f}ms;"
                 f"mixed_stall_events={met['mixed']['stall_events']}"))
    return rows, bounded


def run(fast: bool = False):
    rows, _, _ = compile_comparison(fast=fast)
    rows += load_comparison(fast=fast)
    rows += mixed_prefill_comparison(fast=fast)[0]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    rows, complete, fewer = compile_comparison(fast=args.fast)
    rows += load_comparison(fast=args.fast)
    mixed_rows, bounded = mixed_prefill_comparison(fast=args.fast)
    rows += mixed_rows
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    assert complete, "bucketed engine dropped requests"
    assert fewer, "bucketed engine did not reduce compiles"
    assert bounded, ("chunked prefill did not bound decode stalls below "
                     "the wave monolith / mixed steps still stalled")
    print("# OK: all requests served, bucketed engine compiles fewer "
          "step functions, chunked+mixed prefill bounds decode stalls "
          "by one chunk")


if __name__ == "__main__":
    main()
