"""Fig. 5 analogue on the REAL serving engine (CPU, reduced config):
activated experts + decode behaviour vs replication ratio, METRO vs
EPLB routing — end-to-end through the actual jitted datapath, not the
simulator.  (Wall-clock on CPU is not a TPU claim; the activated-expert
counts are exact.)"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import EngineConfig, ServingEngine
from repro.sharding.policy import make_dist


def run(ratios=(1.0, 1.5), n_requests=6, gen=8):
    rows = []
    cfg = get_config("qwen3-30b-a3b").reduced()
    for ratio in ratios:
        for algo in ("eplb", "metro"):
            ep = 4
            spd = slots_for_ratio(cfg.num_experts, ep, ratio)
            dist = make_dist(None, ep_size=ep, slots_per_device=spd)
            placement = build_placement(cfg.num_experts, ep, spd)
            params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                             replica_expert=placement.replica_expert)
            eng = ServingEngine(cfg, dist, params,
                                EngineConfig(max_batch=4, max_len=64,
                                             decode_algo=algo,
                                             rebalance_every=16))
            rng = np.random.default_rng(0)
            for i in range(n_requests):
                eng.submit(rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 16))), gen)
            t0 = time.perf_counter()
            s = eng.run()
            wall = time.perf_counter() - t0
            rows.append((
                f"fig5_engine_r{ratio}_{algo}",
                s["decode_step_mean_s"] * 1e6,
                f"requests={s['requests']};"
                f"tput={s['total_token_throughput']:.1f}tok/s;"
                f"wall={wall:.1f}s"))
    return rows
