"""Fig. 11: per-layer decode latency breakdown (attention / dispatch /
top-k+routing / FFN / combine) for Qwen3-30B at various replication
ratios — shows METRO's FFN reduction dwarfs its routing overhead."""
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.core.metrics import A100_40G
from repro.sim import (ParallelismConfig, WorkloadConfig,
                       decode_layer_breakdown, synth_topk_batch)
from repro.sim.roofline import _route_stats


def run(ratios=(1.125, 1.25, 1.5), ep=8, batch=256):
    cfg = get_config("qwen3-30b-a3b")
    par = ParallelismConfig(tp=1, ep=ep)
    hw = A100_40G
    wl = WorkloadConfig(zipf_alpha=1.2)
    rows = []
    rng = np.random.default_rng(0)
    for ratio in ratios:
        spd = slots_for_ratio(cfg.num_experts, ep, ratio)
        loads = 1.0 / np.power(np.arange(1, cfg.num_experts + 1), 1.2)
        p = build_placement(cfg.num_experts, ep, spd,
                            loads=rng.permutation(loads))
        ids = synth_topk_batch(rng, cfg.num_experts, batch,
                               cfg.num_experts_per_tok, wl.zipf_alpha)
        for algo, overhead in (("eplb", 0.0), ("metro", 26e-6)):
            act, tok = _route_stats(cfg, p, ids, algo)
            br = decode_layer_breakdown(cfg, hw, par, batch, 2048,
                                        act, tok)
            total = br["total"] + overhead
            rows.append((
                f"fig11_r{ratio}_{algo}", total * 1e6,
                f"attn={br['attn']*1e6:.0f}us;ffn={br['ffn']*1e6:.0f}us;"
                f"route={overhead*1e6:.0f}us;"
                f"comm={(br['dispatch']+br['combine'])*1e6:.0f}us;"
                f"act_max={act.max()}"))
    return rows
