"""Figs. 12/13: decode throughput-latency Pareto frontier across batch
sizes and TP x EP mappings, METRO vs EPLB vs no-replication.

Paper: METRO delivers 1.98-4.11x higher decode throughput at fixed TPOT
SLO; at extremely strict SLOs small batches become network-latency bound
and full TP wins (no EP balancing needed).
"""
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.core.metrics import B200
from repro.sim import ParallelismConfig, WorkloadConfig, simulate_decode_step

SETUPS = [
    ("qwen3-235b-a22b", 8, (1024, 512, 256, 128, 64), (1, 2, 4, 8)),
    ("deepseek-v3-671b", 16, (1024, 512, 256, 128), (1, 2, 4, 8, 16)),
]


def pareto_frontier(points):
    """points: list of (tput, tpot, tag); keep max-tput per tpot level."""
    pts = sorted(points, key=lambda p: p[1])
    out, best = [], -1.0
    for tput, tpot, tag in pts:
        if tput > best:
            out.append((tput, tpot, tag))
            best = tput
    return out


def run(ratios=(1.0, 1.5), ctx=2048):
    rows = []
    wl = WorkloadConfig(zipf_alpha=1.2)
    for model, chips, batches, tps in SETUPS:
        cfg = get_config(model)
        for algo in ("eplb", "metro"):
            for ratio in ratios:
                pts = []
                for tp in tps:
                    ep = chips // tp
                    if ep < 1:
                        continue
                    par = ParallelismConfig(tp=tp, ep=ep)
                    rng = np.random.default_rng(7)
                    spd = slots_for_ratio(cfg.num_experts, ep, ratio)
                    loads = 1.0 / np.power(
                        np.arange(1, cfg.num_experts + 1), 1.2)
                    p = build_placement(cfg.num_experts, ep, spd,
                                        loads=rng.permutation(loads))
                    for b in batches:
                        r = simulate_decode_step(
                            cfg, B200, par, b, ctx, algo, p, wl, rng,
                            routing_overhead=26e-6)
                        pts.append((b / r["step_s"], r["step_s"],
                                    f"tp{tp}ep{ep}b{b}"))
                front = pareto_frontier(pts)
                best_tput = max(p[0] for p in front)
                best_lat = min(p[1] for p in front)
                rows.append((
                    f"fig12_{model}_{algo}_r{ratio}",
                    best_lat * 1e6,
                    f"max_decode_tput={best_tput:.0f}tok/s;"
                    f"frontier={'|'.join(t for _, _, t in front[:4])}"))
    # fixed-SLO comparison (the 1.98-4.11x claim)
    for model, chips, batches, tps in SETUPS:
        cfg = get_config(model)
        slo = None
        best = {}
        for algo in ("eplb", "metro"):
            pts = []
            for tp in tps:
                ep = chips // tp
                par = ParallelismConfig(tp=tp, ep=ep)
                rng = np.random.default_rng(7)
                spd = slots_for_ratio(cfg.num_experts, ep, 1.5)
                loads = 1.0 / np.power(
                    np.arange(1, cfg.num_experts + 1), 1.2)
                p = build_placement(cfg.num_experts, ep, spd,
                                    loads=rng.permutation(loads))
                for b in batches:
                    r = simulate_decode_step(cfg, B200, par, b, ctx,
                                             algo, p, wl, rng,
                                             routing_overhead=26e-6)
                    pts.append((b / r["step_s"], r["step_s"]))
            best[algo] = pts
        # SLO = median EPLB tpot; max tput under it per algo
        slo = float(np.median([t for _, t in best["eplb"]]))
        tput = {a: max([tp for tp, t in best[a] if t <= slo] or [1e-9])
                for a in best}
        rows.append((
            f"fig12_sloratio_{model}", slo * 1e6,
            f"metro_vs_eplb_tput_at_slo="
            f"{tput['metro']/tput['eplb']:.2f}x"))
    return rows
