"""Throughput-at-fixed-SLO Pareto: METRO vs EPLB at cluster scale.

The paper's headline serving claim (Fig. 9–12): at a FIXED decode-
latency SLO, METRO routing sustains a higher request rate than EPLB's
token-balanced routing, because balancing *activated experts* (not
tokens) directly shrinks the memory-bound decode step.  This driver is
the repo's first end-to-end reproduction of that quantity, measured
through the real multi-replica serving stack:

  * N ``ServingEngine`` replicas behind the cluster router
    (``serving/cluster.py``), chunked+mixed prefill, paged KV, shared
    EPLB placement — the whole PR-1/2/3 machinery, not the simulator.
  * **Deterministic virtual time**: every step charges the cost model
    ``default_step_cost`` — decode cost proportional to the step's
    observed ``max_activated`` (max activated experts per device, the
    paper's memory-bound quantity).  METRO's advantage therefore comes
    from its real routing decisions on the real request mix; the same
    seed reproduces every percentile bit-for-bit, which is what lets a
    binary search over arrival rates terminate on exact comparisons.
  * **The sweep**: calibrate the TPOT p99 at a near-idle rate and at
    saturation (EPLB baseline), fix the SLO target between them, then
    binary-search per algorithm for the maximum Poisson arrival rate
    whose open-loop replay still meets ``tpot_p99 <= target`` with
    every request served.

Self-checks (deterministic, asserted):
  * calibration brackets the target for both algorithms
    (feasible at the low rate, infeasible at the saturation rate);
  * re-running the winning rate reproduces the summary exactly;
  * METRO's max sustainable rate >= EPLB's (the paper's direction).

Run:  PYTHONPATH=src python benchmarks/bench_pareto_slo.py [--fast]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                           TrafficConfig, generate_trace)
from repro.serving.cluster import default_step_cost
from repro.sharding.policy import make_dist


@dataclasses.dataclass
class ParetoSetup:
    arch: str = "qwen3-30b-a3b"
    num_replicas: int = 2
    max_batch: int = 8
    max_len: int = 64
    prefill_chunk: int = 16
    num_requests: int = 48
    seed: int = 11
    slo_weight: float = 0.35    # target = base + w * (sat - base)
    search_iters: int = 6
    rate_lo: float = 50.0       # near-idle calibration rate (req/s)
    rate_cap: float = 1e5       # bracket-doubling safety cap
    # --- virtual-clock step-cost model ---
    cost_model: str = "activated"   # "activated": cluster.default_step_cost
                                    # (decode charges raw max_activated);
                                    # "roofline": sim.roofline per-impl
                                    # HBM-bytes model — shows the fused
                                    # kernel's latency headroom
    moe_impl: str = "ragged"        # engine expert datapath; also picks
                                    # the roofline traffic account
                                    # ("fused"/"fused_paged" -> fused,
                                    # else two_pass)
    # --- expert-weight pool (serving/expert_pool.py) ---
    expert_pool: bool = False       # page expert weights host<->HBM
    hbm_budget_frac: float = 0.0    # pool frames as a fraction of the
                                    # full weight set (0 -> all frames);
                                    # with cost_model="roofline" the
                                    # miss/gate bytes serialize into the
                                    # step and prefetch overlaps it
    prefetch_depth: int = 8


def build_model(setup: ParetoSetup):
    cfg = get_config(setup.arch).reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25) if cfg.is_moe else 1
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, ep, spd)
                 if cfg.is_moe else None)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert
                     if placement else None)
    return cfg, dist, params


def make_trace(cfg, setup: ParetoSetup, rate: float):
    return generate_trace(TrafficConfig(
        num_requests=setup.num_requests, arrival_rate=rate,
        seed=setup.seed, prompt_len_mean=8, prompt_len_max=24,
        output_len_mean=8, output_len_sigma=0.3, output_len_max=12,
        tail_fraction=0.15, tail_scale=2.5, vocab_size=cfg.vocab_size))


class ParetoProbe:
    """One (algo -> cluster factory) with a shared jit cache so the
    rate sweep compiles each step signature exactly once."""

    def __init__(self, cfg, dist, params, setup: ParetoSetup, algo: str):
        self.cfg, self.dist, self.params = cfg, dist, params
        self.setup = setup
        budget = 0
        if setup.expert_pool and setup.hbm_budget_frac > 0:
            from repro.serving import expert_page_bytes, moe_layer_count
            total = (expert_page_bytes(cfg) * moe_layer_count(cfg)
                     * dist.num_slots)
            budget = int(total * setup.hbm_budget_frac)
        self.ecfg = EngineConfig(
            max_batch=setup.max_batch, max_len=setup.max_len,
            prefill_chunk=setup.prefill_chunk, decode_algo=algo,
            moe_impl=setup.moe_impl, rebalance_every=0,
            expert_pool=setup.expert_pool, hbm_budget_bytes=budget,
            prefetch_depth=setup.prefetch_depth)
        if setup.cost_model == "roofline":
            from repro.sim import make_roofline_step_cost
            traffic_impl = ("fused" if setup.moe_impl
                            in ("fused", "fused_paged") else "two_pass")
            self.step_cost = make_roofline_step_cost(cfg, traffic_impl)
        else:
            assert setup.cost_model == "activated", setup.cost_model
            self.step_cost = default_step_cost
        self.fn_cache = {"decode": {}, "prefill": {}, "chunk": {},
                         "mixed": {}}
        self.runs = 0

    def run(self, rate: float) -> dict:
        clus = ClusterEngine(
            self.cfg, self.dist, self.params, self.ecfg,
            ClusterConfig(num_replicas=self.setup.num_replicas,
                          dispatch="low"),
            step_cost=self.step_cost,
            fn_cache=self.fn_cache)
        s = clus.replay_open_loop(make_trace(self.cfg, self.setup, rate))
        self.runs += 1
        return s

    def feasible(self, rate: float, target: float) -> bool:
        s = self.run(rate)
        return (s["requests"] == self.setup.num_requests
                and s["tpot_p99"] <= target)

    def max_rate(self, target: float) -> float:
        """Binary-search the max arrival rate meeting the TPOT target."""
        setup = self.setup
        lo = setup.rate_lo
        assert self.feasible(lo, target), \
            "calibration rate infeasible — target below the idle TPOT"
        hi = lo * 2
        while self.feasible(hi, target):
            lo = hi
            if hi >= setup.rate_cap:
                return hi              # feasible at the cap itself:
                                       # never saturated below it
            hi *= 2
        for _ in range(setup.search_iters):
            mid = 0.5 * (lo + hi)
            if self.feasible(mid, target):
                lo = mid
            else:
                hi = mid
        return lo


def run(fast: bool = False, setup: ParetoSetup = None):
    setup = setup or ParetoSetup()
    if fast:
        setup = dataclasses.replace(setup, num_requests=20,
                                    search_iters=4)
    cfg, dist, params = build_model(setup)
    probes = {a: ParetoProbe(cfg, dist, params, setup, a)
              for a in ("eplb", "metro")}

    # --- calibrate the SLO target from the EPLB baseline ---
    base = {a: p.run(setup.rate_lo)["tpot_p99"]
            for a, p in probes.items()}
    sat = {a: p.run(setup.rate_cap)["tpot_p99"]
           for a, p in probes.items()}
    target = base["eplb"] + setup.slo_weight * (sat["eplb"] - base["eplb"])
    bracketed = all(base[a] <= target < sat[a] for a in probes)

    rows = [("pareto_slo_target", target * 1e6,
             f"tpot_p99_target={target * 1e3:.3f}ms;"
             f"base_eplb={base['eplb'] * 1e3:.3f}ms;"
             f"sat_eplb={sat['eplb'] * 1e3:.3f}ms;"
             f"base_metro={base['metro'] * 1e3:.3f}ms;"
             f"sat_metro={sat['metro'] * 1e3:.3f}ms;"
             f"bracketed={bracketed};"
             f"cost_model={setup.cost_model};moe_impl={setup.moe_impl};"
             f"expert_pool={setup.expert_pool};"
             f"hbm_budget_frac={setup.hbm_budget_frac}")]

    # --- the Pareto point: max sustainable rate at the fixed target ---
    rates, at_rate = {}, {}
    for a, p in probes.items():
        t0 = time.perf_counter()
        rates[a] = p.max_rate(target)
        at_rate[a] = p.run(rates[a])
        rows.append((
            f"pareto_slo_{a}", rates[a],
            f"max_rate={rates[a]:.1f}req/s;"
            f"tpot_p99={at_rate[a]['tpot_p99'] * 1e3:.3f}ms;"
            f"ttft_p99={at_rate[a]['ttft_p99'] * 1e3:.2f}ms;"
            f"tput={at_rate[a]['total_token_throughput']:.0f}tok/s;"
            f"requests={at_rate[a]['requests']};"
            f"replicas={setup.num_replicas};probes={p.runs};"
            f"wall={time.perf_counter() - t0:.1f}s"))

    ratio = rates["metro"] / max(rates["eplb"], 1e-9)
    # deterministic self-check: the winning METRO rate replayed again
    # must reproduce the summary exactly (virtual time, fixed seeds)
    again = probes["metro"].run(rates["metro"])
    deterministic = (
        again["tpot_p99"] == at_rate["metro"]["tpot_p99"]
        and again["ttft_p99"] == at_rate["metro"]["ttft_p99"]
        and again["requests"] == at_rate["metro"]["requests"])
    complete = all(at_rate[a]["requests"] == setup.num_requests
                   for a in probes)
    rows.append((
        "pareto_slo_check", ratio,
        f"metro_over_eplb_rate={ratio:.3f};deterministic={deterministic};"
        f"all_complete={complete};bracketed={bracketed}"))
    checks = {"bracketed": bracketed, "deterministic": deterministic,
              "complete": complete, "ratio": ratio}
    return rows, checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--cost-model", default="activated",
                    choices=("activated", "roofline"),
                    help="decode step cost: raw max_activated or the "
                         "per-impl roofline HBM-bytes model")
    ap.add_argument("--moe-impl", default="ragged",
                    choices=("ragged", "scan_tiles", "pallas", "fused",
                             "fused_paged"),
                    help="engine expert-FFN datapath (also selects the "
                         "roofline traffic account)")
    ap.add_argument("--expert-pool", action="store_true",
                    help="enable the paged expert-weight pool")
    ap.add_argument("--hbm-budget-frac", type=float, default=0.0,
                    help="pool HBM budget as a fraction of the full "
                         "expert weight set (0 = all-resident)")
    args = ap.parse_args()
    rows, checks = run(fast=args.fast, setup=ParetoSetup(
        cost_model=args.cost_model, moe_impl=args.moe_impl,
        expert_pool=args.expert_pool,
        hbm_budget_frac=args.hbm_budget_frac))
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    assert checks["complete"], "a probe dropped requests"
    assert checks["bracketed"], \
        "SLO target not bracketed by idle/saturation TPOT"
    assert checks["deterministic"], \
        "virtual-time replay was not bit-reproducible"
    assert checks["ratio"] >= 1.0, \
        "METRO sustained a lower rate than EPLB at the fixed SLO"
    print("# OK: deterministic Pareto point; METRO sustains "
          f"{checks['ratio']:.2f}x EPLB's rate at the fixed TPOT p99 SLO")


if __name__ == "__main__":
    main()
