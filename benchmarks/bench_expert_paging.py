"""Expert-weight paging: tokens/s vs HBM budget, METRO vs EPLB.

The paper serves MoE models in the memory-bound regime; this driver
asks what happens when the expert weights themselves do not fit HBM.
The serving engine pages per-(layer, slot) expert weights through a
bounded frame pool (``serving/expert_pool.py``) with the router's
step-``t`` output prefetching step ``t+1``'s pages, and virtual time
charges host<->HBM traffic through the pool-aware roofline model
(``sim/roofline.py``): demand misses and residency-gate flushes are
serial, prefetch overlaps compute (the double-buffered DMA path in
``kernels/moe_ffn.py``).

The sweep serves one fixed trace per (algo in {metro, eplb} x HBM
budget fraction x prefetch on/off) cell and reports virtual tokens/s
plus the pool's counters.  Deterministic self-checks, asserted:

  * **parity** — served tokens under every capacity-limited pool are
    bit-identical to the all-resident run (the pool is bookkeeping +
    cost, never math);
  * **balance** — at the tightest budget (one layer's slot set: full
    thrash) METRO moves strictly fewer demand host<->HBM bytes than
    EPLB: token-balancing splits an expert's tokens across replica
    slots, activating more distinct pages per step — the paper's
    activated-expert argument applied to the host link;
  * **dead tiles** — with the pool enabled dead tiles still move zero
    weight bytes: the paged megakernel's explicit per-live-tile DMA
    issues nothing for dead tiles (an all-dead grid is exact zeros
    with no copies), the automatic pipeline's DMA-count model is
    unchanged by appended dead tiles, and a step that activates
    nothing acquires no pages.

Run:  PYTHONPATH=src python benchmarks/bench_expert_paging.py [--fast]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import (EngineConfig, ServingEngine, VirtualClock,
                           expert_page_bytes, moe_layer_count)
from repro.sharding.policy import make_dist
from repro.sim import fused_weight_dma_tiles, make_roofline_step_cost


@dataclasses.dataclass(frozen=True)
class PagingSetup:
    arch: str = "mixtral-8x22b"
    ep: int = 4
    replication: float = 1.25
    max_batch: int = 8
    max_len: int = 64
    moe_impl: str = "ragged"     # datapath; roofline charges "fused"
    # decode batches must be big enough that several tokens hit the
    # same expert per step — that is where EPLB's token-balancing
    # splits across replica slots (more pages) and METRO packs
    prompt_lens: tuple = (5, 9, 3, 7, 4, 6, 8, 5, 6, 4, 7, 9)
    max_new: int = 12
    prefetch_depth: int = 8
    seed: int = 7
    # budget fractions of the full expert weight set; 0.0 is replaced
    # by the tightest legal pool (one layer's slot set -> full thrash)
    budget_fracs: tuple = (1.0, 0.75, 0.0)


def _build(setup: PagingSetup):
    cfg = get_config(setup.arch).reduced()
    spd = slots_for_ratio(cfg.num_experts, setup.ep, setup.replication)
    dist = make_dist(None, ep_size=setup.ep, slots_per_device=spd)
    placement = build_placement(cfg.num_experts, setup.ep, spd)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert)
    return cfg, dist, params


def serve_paged(setup: PagingSetup, cfg, dist, params, *, algo: str,
                budget_bytes: int, prefetch_depth: int,
                expert_pool: bool = True, fn_cache=None):
    """Serve the fixed trace; returns (tokens, tokens/s, engine)."""
    ecfg = EngineConfig(
        max_batch=setup.max_batch, max_len=setup.max_len,
        moe_impl=setup.moe_impl, decode_algo=algo, rebalance_every=0,
        expert_pool=expert_pool, hbm_budget_bytes=budget_bytes,
        prefetch_depth=prefetch_depth)
    clock = VirtualClock()
    traffic_impl = ("fused" if setup.moe_impl in ("fused", "fused_paged")
                    else "two_pass")
    eng = ServingEngine(cfg, dist, params, ecfg, clock=clock,
                        step_cost=make_roofline_step_cost(
                            cfg, traffic_impl),
                        fn_cache=fn_cache)
    rng = np.random.default_rng(setup.seed)
    n_tok = 0
    for n in setup.prompt_lens:
        eng.submit(rng.integers(0, cfg.vocab_size, n), setup.max_new)
    eng.run()
    tokens = {rid: tuple(r.generated) for rid, r in eng.completed.items()}
    n_tok = sum(len(t) for t in tokens.values())
    tps = n_tok / clock.t if clock.t > 0 else 0.0
    return tokens, tps, eng


def _decode_demand_bytes(pool) -> int:
    """Serial decode-step host<->HBM bytes: demand misses + residency-
    gate flushes.  Prefetch bytes are excluded — they overlap compute
    and saturate the depth budget identically across algorithms."""
    per = pool.bytes_by_kind.get("decode", {})
    return per.get("miss", 0) + per.get("gate", 0)


def check_dead_tiles_zero_dma() -> bool:
    """Pool enabled or not, dead tiles move zero weight bytes."""
    # (1) paged megakernel: an all-dead grid issues no copies and
    # writes exact zeros (the copies are pl.when-guarded per live tile)
    from repro.kernels.moe_ffn import fused_expert_ffn_paged_pallas
    rng = np.random.default_rng(0)
    d, fe, s, tile = 16, 24, 3, 4
    wu = jnp.asarray(rng.normal(size=(s, d, 2 * fe)), jnp.float32)
    wd = jnp.asarray(rng.normal(size=(s, fe, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2 * tile, d)), jnp.float32)
    fm = jnp.arange(s, dtype=jnp.int32)
    tg = jnp.asarray([-1, -1], jnp.int32)
    all_dead_zero = not np.asarray(
        fused_expert_ffn_paged_pallas(x, wu, wd, fm, tg,
                                      gated=True)).any()
    # (2) automatic pipeline: appending dead tiles to a live grid does
    # not change the DMA-tile count (they park on resident blocks)
    live = fused_weight_dma_tiles(np.array([0, 2, 1]), 2, 2)
    padded = fused_weight_dma_tiles(np.array([0, 2, 1, -1, -1]), 2, 2)
    park_free = live["dma_tiles"] == padded["dma_tiles"]
    # (3) pool: a step that activates nothing acquires nothing
    from repro.serving.expert_pool import ExpertPagePool
    pool = ExpertPagePool(n_layers=1, n_slots=2, page_bytes=8,
                          num_frames=2)
    res = pool.acquire([], kind="decode")
    no_access = (res["miss_bytes"] == 0
                 and pool.counters()["h2d_bytes"] == 0)
    return all_dead_zero and park_free and no_access


def run(fast: bool = False, setup: PagingSetup = None):
    setup = setup or PagingSetup()
    if fast:
        # keep the full trace (the balance check needs loaded decode
        # batches); trim the budget sweep to its endpoints
        setup = dataclasses.replace(setup, budget_fracs=(1.0, 0.0))
    cfg, dist, params = _build(setup)
    pb = expert_page_bytes(cfg)
    n_layers = moe_layer_count(cfg)
    total_bytes = pb * n_layers * dist.num_slots
    tight_bytes = pb * dist.num_slots      # one layer's slot set

    rows = []
    # one fn_cache PER ALGORITHM: the compiled step functions bake in
    # decode_algo, so sharing across algos would replay the first
    # algo's routing (bench_pareto_slo keeps per-probe caches for the
    # same reason)
    caches = {a: {"decode": {}, "prefill": {}, "chunk": {}, "mixed": {}}
              for a in ("metro", "eplb")}
    # --- baseline: pool disabled (ordinary all-weights-in-HBM serve) -
    base_tokens = {}
    for algo in ("metro", "eplb"):
        toks, tps, _ = serve_paged(setup, cfg, dist, params, algo=algo,
                                   budget_bytes=0, prefetch_depth=0,
                                   expert_pool=False,
                                   fn_cache=caches[algo])
        base_tokens[algo] = toks
        rows.append((f"expert_paging_{algo}_nopool", tps,
                     f"tokens_per_s={tps:.0f};budget=none;"
                     f"tokens={sum(len(t) for t in toks.values())}"))

    # --- the sweep: budget x algo x prefetch on/off ------------------
    parity = True
    demand_at_tight = {}
    for frac in setup.budget_fracs:
        budget = int(total_bytes * frac) if frac > 0 else tight_bytes
        label = f"{frac:.2f}" if frac > 0 else "tight"
        for algo in ("metro", "eplb"):
            for depth in (setup.prefetch_depth, 0):
                toks, tps, eng = serve_paged(
                    setup, cfg, dist, params, algo=algo,
                    budget_bytes=budget, prefetch_depth=depth,
                    fn_cache=caches[algo])
                pool = eng.expert_pool
                pool.check_consistent()
                parity &= toks == base_tokens[algo]
                c = pool.counters()
                if label == "tight" and depth == setup.prefetch_depth:
                    demand_at_tight[algo] = _decode_demand_bytes(pool)
                pf = "on" if depth else "off"
                rows.append((
                    f"expert_paging_{algo}_b{label}_pf{pf}", tps,
                    f"tokens_per_s={tps:.0f};frames={c['num_frames']};"
                    f"hit_rate={c['hit_rate']:.3f};"
                    f"coverage={c['prefetch_coverage']:.3f};"
                    f"h2d_mb={c['h2d_bytes'] / 1e6:.3f};"
                    f"decode_demand_b={_decode_demand_bytes(pool)};"
                    f"evictions={c['evictions']}"))

    balance = demand_at_tight["metro"] < demand_at_tight["eplb"]
    dead = check_dead_tiles_zero_dma()
    rows.append((
        "expert_paging_check",
        demand_at_tight["eplb"] - demand_at_tight["metro"],
        f"parity={parity};metro_demand_b={demand_at_tight['metro']};"
        f"eplb_demand_b={demand_at_tight['eplb']};balance={balance};"
        f"dead_tiles_zero_dma={dead}"))
    checks = {"parity": parity, "balance": balance, "dead_tiles": dead,
              "demand_at_tight": demand_at_tight}
    return rows, checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--moe-impl", default="ragged",
                    choices=("ragged", "scan_tiles", "pallas", "fused",
                             "fused_paged"))
    args = ap.parse_args()
    rows, checks = run(fast=args.fast,
                       setup=PagingSetup(moe_impl=args.moe_impl))
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    assert checks["parity"], \
        "capacity-limited pool changed the served tokens"
    assert checks["balance"], \
        "METRO did not beat EPLB on demand host<->HBM bytes"
    assert checks["dead_tiles"], "dead tiles moved weight bytes"
    print("# OK: pool serve bit-identical; METRO demand bytes "
          f"{checks['demand_at_tight']['metro']} < EPLB "
          f"{checks['demand_at_tight']['eplb']} at the tightest budget")


if __name__ == "__main__":
    main()
