"""Fig. 8: max activated experts per device per decode batch —
EPLB routing vs METRO vs the optimal algorithm.

Paper: METRO within 10.9% of optimal, up to 42.3% below EPLB, across
DeepSeek-V3/Qwen3-30B x Humaneval/GSM8K x replication ratios.
"""
import numpy as np
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (build_placement, optimal, routing_stats,
                        slots_for_ratio)
from repro.core import routing as R
from repro.sim import synth_topk_batch

WORKLOADS = {"humaneval-like": 1.2, "gsm8k-like": 0.8}


def run(models=("qwen3-30b-a3b", "deepseek-v3-671b"),
        ratios=(1.125, 1.25, 1.5), ep=8, batch=32, trials=8):
    rows = []
    for name in models:
        cfg = get_config(name)
        n, k = cfg.num_experts, cfg.num_experts_per_tok
        for wl, alpha in WORKLOADS.items():
            for ratio in ratios:
                rng = np.random.default_rng(hash((name, wl)) % 2**32)
                spd = slots_for_ratio(n, ep, ratio)
                lam = {"eplb": [], "metro": [], "optimal": []}
                for t in range(trials):
                    loads = 1.0 / np.power(np.arange(1, n + 1), alpha)
                    p = build_placement(n, ep, spd,
                                        loads=rng.permutation(loads))
                    ids = synth_topk_batch(rng, n, batch, k, alpha)
                    idsj = jnp.asarray(ids, jnp.int32)
                    hist = R.topk_histogram(idsj, n)
                    for algo in ("eplb", "metro"):
                        slots = R.route(
                            algo, idsj, hist,
                            jnp.asarray(p.expert_slots),
                            jnp.asarray(p.expert_num_replicas),
                            num_devices=ep, slots_per_device=spd)
                        lam[algo].append(
                            routing_stats(slots, p).max_activated)
                    lam["optimal"].append(optimal.optimal_lambda(
                        np.asarray(hist), p.placement_matrix()))
                e, m, o = (float(np.mean(lam[a]))
                           for a in ("eplb", "metro", "optimal"))
                rows.append((
                    f"fig8_{name}_{wl}_r{ratio}",
                    m,
                    f"eplb={e:.1f};optimal={o:.1f};"
                    f"metro_vs_eplb={-100*(1-m/e):.1f}%;"
                    f"metro_vs_opt=+{100*(m/o-1):.1f}%"))
    return rows
