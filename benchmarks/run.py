"""Benchmark driver — one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows.

  fig5_engine           real serving engine (CPU, reduced config)
  fig6_routing_overhead optimal vs METRO routing wall-clock
  fig8_activated        max activated experts: EPLB / METRO / optimal
  fig9_10_e2e           simulated TPOT + total throughput
  fig11_breakdown       per-layer latency breakdown
  fig12_pareto          decode Pareto frontier over TPxEPxbatch
  engine_scale          bucketing/paging compile discipline + Poisson load
  pareto_slo            cluster throughput-at-fixed-SLO (METRO vs EPLB)
  prefix_cache          TTFT/pages-saved vs prefix-hit rate (METRO vs EPLB)
  moe_kernels           fused expert-FFN megakernel vs two-pass (HBM
                        bytes model + dead-tile DMA accounting)
  expert_paging         tokens/s vs HBM budget through the paged
                        expert-weight pool (METRO vs EPLB, prefetch
                        on/off)

Regression recording: ``--record`` persists the deterministic
virtual-clock metrics of the suites in ``RECORDED`` to
``BENCH_<suite>.json`` at the repo root; ``--check`` compares a fresh
run against the recorded numbers within ``REL_TOL`` and exits 1 on
drift.  Only fast-mode proxy numbers are recorded (CI runs the check
with ``--fast``); the nightly full sweeps rely on each bench's own
asserts instead.
"""
import argparse
import json
import os
import sys
import time

# make `from benchmarks import ...` (and `repro` without an installed
# wheel) work when invoked as a script: python benchmarks/run.py puts
# benchmarks/ itself on sys.path, not the repo root
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

RECORDED = ("expert_paging", "pareto_slo")
REL_TOL = 0.10


def _bench_path(key: str) -> str:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return os.path.join(root, f"BENCH_{key}.json")


def _record(key: str, rows, fast: bool) -> None:
    payload = {"suite": key, "mode": "fast" if fast else "full",
               "rel_tol": REL_TOL,
               "rows": {name: val for name, val, _ in rows}}
    with open(_bench_path(key), "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# recorded {len(rows)} rows -> BENCH_{key}.json",
          file=sys.stderr)


def _check(key: str, rows, fast: bool) -> list:
    path = _bench_path(key)
    if not os.path.exists(path):
        return [f"{key}: no recorded baseline ({path})"]
    with open(path) as f:
        ref = json.load(f)
    if ref.get("mode") != ("fast" if fast else "full"):
        return [f"{key}: baseline recorded in {ref.get('mode')} mode, "
                f"run with matching --fast to compare"]
    got = {name: val for name, val, _ in rows}
    errs = []
    for name, want in ref["rows"].items():
        if name not in got:
            errs.append(f"{key}: row {name} missing from this run")
            continue
        tol = REL_TOL * max(abs(want), 1e-9)
        if abs(got[name] - want) > tol:
            errs.append(f"{key}: {name} = {got[name]:.1f}, recorded "
                        f"{want:.1f} (>{REL_TOL:.0%} drift)")
    return errs


def _asserted(rows_checks):
    """Unwrap a (rows, checks) bench result, enforcing every boolean
    self-check (the standalone main()s assert the same flags)."""
    rows, checks = rows_checks
    bad = [k for k, v in checks.items()
           if isinstance(v, bool) and not v]
    assert not bad, f"self-checks failed: {bad}"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark prefixes to run")
    ap.add_argument("--fast", action="store_true",
                    help="reduced trial counts")
    ap.add_argument("--record", action="store_true",
                    help="persist deterministic metrics of recordable "
                         "suites to BENCH_<suite>.json")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) if a recordable suite drifts "
                         "from its BENCH_<suite>.json baseline")
    args = ap.parse_args()

    from benchmarks import (bench_engine_scale, bench_expert_paging,
                            bench_moe_kernels, bench_pareto_slo,
                            bench_prefix_cache, fig5_engine,
                            fig6_routing_overhead,
                            fig8_activated_experts, fig9_10_e2e,
                            fig11_breakdown, fig12_pareto)
    suites = {
        "engine_scale": lambda: bench_engine_scale.run(fast=args.fast),
        "pareto_slo": lambda: _asserted(
            bench_pareto_slo.run(fast=args.fast)),
        "prefix_cache": lambda: bench_prefix_cache.run(
            fast=args.fast)[0],
        "moe_kernels": lambda: bench_moe_kernels.run(fast=args.fast)[0],
        "expert_paging": lambda: _asserted(
            bench_expert_paging.run(fast=args.fast)),
        "fig6": lambda: fig6_routing_overhead.run(),
        "fig8": lambda: fig8_activated_experts.run(
            trials=3 if args.fast else 8),
        "fig9": lambda: fig9_10_e2e.run(),
        "fig11": lambda: fig11_breakdown.run(),
        "fig12": lambda: fig12_pareto.run(),
        "fig5": lambda: fig5_engine.run(),
    }
    only = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    failures = []
    for key, fn in suites.items():
        if only and not any(key.startswith(o) for o in only):
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # keep the suite running
            print(f"{key}_ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            if args.check:
                failures.append(f"{key}: raised {type(e).__name__}")
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        if key in RECORDED:
            if args.record:
                _record(key, rows, args.fast)
            if args.check:
                failures.extend(_check(key, rows, args.fast))
    if failures:
        for f in failures:
            print(f"# REGRESSION {f}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
