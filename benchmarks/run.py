"""Benchmark driver — one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows.

  fig5_engine           real serving engine (CPU, reduced config)
  fig6_routing_overhead optimal vs METRO routing wall-clock
  fig8_activated        max activated experts: EPLB / METRO / optimal
  fig9_10_e2e           simulated TPOT + total throughput
  fig11_breakdown       per-layer latency breakdown
  fig12_pareto          decode Pareto frontier over TPxEPxbatch
  engine_scale          bucketing/paging compile discipline + Poisson load
  pareto_slo            cluster throughput-at-fixed-SLO (METRO vs EPLB)
  prefix_cache          TTFT/pages-saved vs prefix-hit rate (METRO vs EPLB)
  moe_kernels           fused expert-FFN megakernel vs two-pass (HBM
                        bytes model + dead-tile DMA accounting)
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark prefixes to run")
    ap.add_argument("--fast", action="store_true",
                    help="reduced trial counts")
    args = ap.parse_args()

    from benchmarks import (bench_engine_scale, bench_moe_kernels,
                            bench_pareto_slo, bench_prefix_cache,
                            fig5_engine, fig6_routing_overhead,
                            fig8_activated_experts, fig9_10_e2e,
                            fig11_breakdown, fig12_pareto)
    suites = {
        "engine_scale": lambda: bench_engine_scale.run(fast=args.fast),
        "pareto_slo": lambda: bench_pareto_slo.run(fast=args.fast)[0],
        "prefix_cache": lambda: bench_prefix_cache.run(
            fast=args.fast)[0],
        "moe_kernels": lambda: bench_moe_kernels.run(fast=args.fast)[0],
        "fig6": lambda: fig6_routing_overhead.run(),
        "fig8": lambda: fig8_activated_experts.run(
            trials=3 if args.fast else 8),
        "fig9": lambda: fig9_10_e2e.run(),
        "fig11": lambda: fig11_breakdown.run(),
        "fig12": lambda: fig12_pareto.run(),
        "fig5": lambda: fig5_engine.run(),
    }
    only = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    for key, fn in suites.items():
        if only and not any(key.startswith(o) for o in only):
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # keep the suite running
            print(f"{key}_ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
