"""Fig. 6: routing-algorithm runtime — optimal (binary search + max-flow)
vs METRO greedy (jitted scan + Pallas kernel).

The paper measures 116-129us (CPU optimal) and 290us (GPU optimal) vs a
~300us FFN layer; METRO's kernel costs up to 26us on A100.  Here we
wall-clock our implementations on this host; the *ratios* are the
reproduction target (optimal >> greedy).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (build_placement, optimal, route_metro,
                        slots_for_ratio)
from repro.kernels.metro_route import metro_route_pallas
from repro.sim import synth_topk_batch


def _time(f, n=20):
    f()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        f()
    return (time.perf_counter() - t0) / n


def run(models=(("qwen3-30b-a3b", 128), ("deepseek-v3-671b", 256)),
        ratios=(1.125, 1.25, 1.5), ep=8, batch=256, k=8, alpha=1.2):
    rows = []
    rng = np.random.default_rng(0)
    for name, n_exp in models:
        for ratio in ratios:
            spd = slots_for_ratio(n_exp, ep, ratio)
            p = build_placement(n_exp, ep, spd,
                                loads=rng.random(n_exp) + 0.1)
            ids = synth_topk_batch(rng, n_exp, batch, k, alpha)
            hist = np.bincount(ids.reshape(-1), minlength=n_exp)
            hist_j = jnp.asarray(hist, jnp.int32)
            slots_j = jnp.asarray(p.expert_slots)

            t_opt = _time(lambda: optimal.solve_min_exp_routing(
                hist, p.placement_matrix()), n=5)

            def greedy():
                route_metro(hist_j, slots_j, num_devices=ep,
                            slots_per_device=spd).block_until_ready()

            t_greedy = _time(greedy)

            def pallas():
                metro_route_pallas(
                    hist_j, slots_j, num_devices=ep,
                    slots_per_device=spd).block_until_ready()

            t_pallas = _time(pallas, n=5)
            rows.append((f"fig6_{name}_r{ratio}_optimal",
                         t_opt * 1e6, f"ratio_vs_greedy={t_opt/t_greedy:.1f}x"))
            rows.append((f"fig6_{name}_r{ratio}_metro_scan",
                         t_greedy * 1e6, "jitted_lax_scan"))
            rows.append((f"fig6_{name}_r{ratio}_metro_pallas",
                         t_pallas * 1e6, "interpret_mode_cpu"))
    return rows
