"""MoE expert-FFN kernel benchmark: fused megakernel vs two-pass.

Sweeps batch/activation regimes (decode-tiny to prefill-wide, balanced
to heavily skewed routing), builds the REAL pair buffer each regime
produces (``moe.build_pair_buffer``), and for every config:

  * charges the analytic HBM-bytes model (``sim.roofline
    .expert_ffn_traffic``) per impl and **asserts the fused path's
    modeled traffic is strictly below two-pass** (both the seed's
    dead-tile-DMA-ing legacy account and this PR's dead-tile-skipping
    two-pass) — the paper's §III-B claim applied to the kernel itself;
  * replays the fused kernel's BlockSpec index maps with Pallas
    revisit-skip semantics (``sim.roofline.fused_weight_dma_tiles``)
    and **asserts the weight-tile DMA count equals the live-tile
    count** — dead tiles (METRO's no-drop padding) fetch nothing;
  * times the jitted impls on the same buffers (CPU interpret mode for
    the Pallas paths — wall numbers are relative only).

The engine-level check (``moe_impl="fused"`` serve is token-identical
to ``"ragged"``) runs in main() and in tests/test_moe_fused.py.

Run:  PYTHONPATH=src python benchmarks/bench_moe_kernels.py [--fast]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_ffn import fused_expert_ffn_pallas
from repro.models.moe import build_pair_buffer, grouped_matmul
from repro.sim.roofline import expert_ffn_traffic, fused_weight_dma_tiles


@dataclasses.dataclass(frozen=True)
class SweepCase:
    name: str
    tokens: int        # tokens in the local batch
    k: int             # experts per token
    s_loc: int         # local expert slots
    hot_frac: float    # fraction of pairs landing on one hot slot
                       # (1.0 -> everything on slot 0; 0 -> uniform)
    d: int = 64
    fe: int = 96
    gated: bool = True
    tile: int = 8


CASES = [
    SweepCase("decode_tiny_uniform", tokens=4, k=2, s_loc=4, hot_frac=0.0),
    SweepCase("decode_tiny_skewed", tokens=4, k=2, s_loc=4, hot_frac=0.9),
    SweepCase("decode_batch_uniform", tokens=32, k=2, s_loc=8,
              hot_frac=0.0),
    SweepCase("decode_batch_skewed", tokens=32, k=2, s_loc=8,
              hot_frac=0.8),
    SweepCase("prefill_wide_uniform", tokens=128, k=2, s_loc=8,
              hot_frac=0.0, gated=False),
    SweepCase("prefill_wide_skewed", tokens=128, k=4, s_loc=8,
              hot_frac=0.7),
    SweepCase("mostly_remote", tokens=24, k=2, s_loc=4, hot_frac=0.0),
]


def build_case(case: SweepCase, seed: int = 0):
    """Synthesize routing for one regime and build the pair buffer."""
    rng = np.random.default_rng(seed)
    total = case.s_loc * 2 if case.name == "mostly_remote" else case.s_loc
    slots = rng.integers(0, total, (case.tokens, case.k)).astype(np.int32)
    hot = rng.random((case.tokens, case.k)) < case.hot_frac
    slots = np.where(hot, 0, slots)
    # METRO no-drop capacity: all T*k pairs, tile-padded slack
    pairs = case.tokens * case.k
    capacity = int(np.ceil(
        (pairs + case.s_loc * (case.tile - 1)) / case.tile)) * case.tile
    buf_pair, group_pad, tile_group, n_live = jax.jit(
        build_pair_buffer, static_argnames=("s_loc", "capacity", "tile")
    )(jnp.asarray(slots), 0, s_loc=case.s_loc, capacity=capacity,
      tile=case.tile)
    return (np.asarray(buf_pair), np.asarray(group_pad),
            np.asarray(tile_group), int(n_live), capacity)


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)                     # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = False, seed: int = 0):
    rows, checks = [], {"traffic": True, "dma": True}
    cases = CASES[:4] if fast else CASES
    for case in cases:
        buf_pair, group_pad, tile_group, n_live, capacity = \
            build_case(case, seed)
        n_tiles = capacity // case.tile
        n_up = 2 if case.gated else 1

        # ---- analytic HBM traffic: fused strictly below two-pass ----
        tr = {impl: expert_ffn_traffic(
            impl, d=case.d, fe=case.fe, n_up=n_up, tile_m=case.tile,
            n_tiles=n_tiles, live_tiles=n_live)
            for impl in ("fused", "two_pass", "two_pass_legacy")}
        below = (tr["fused"]["total"] < tr["two_pass"]["total"]
                 and tr["fused"]["total"] < tr["two_pass_legacy"]["total"])
        checks["traffic"] &= below

        # ---- DMA emulation: dead tiles fetch nothing ----------------
        # (same tile_k the fused kernel below is invoked with, so the
        # emulated index maps ARE the timed kernel's)
        tile_k = 32
        tile_k_up = min(tile_k, case.d)
        tile_k_dn = min(tile_k, case.fe)
        k_up = case.d // tile_k_up
        k_dn = case.fe // tile_k_dn
        dma = fused_weight_dma_tiles(tile_group, k_up, k_dn)
        live_only = tile_group[tile_group >= 0]
        dma_live = fused_weight_dma_tiles(live_only, k_up, k_dn)
        dma_ok = (dma["m_tiles"] == n_live
                  and dma["dma_tiles"] == dma_live["dma_tiles"]
                  and dma["dma_tiles"] <= n_live * (k_up + k_dn))
        checks["dma"] &= dma_ok

        # ---- wall time on the real buffers (interpret mode) ---------
        rng = np.random.default_rng(seed + 1)
        x = jnp.asarray(rng.normal(size=(capacity, case.d)), jnp.float32)
        wu = jnp.asarray(
            rng.normal(size=(case.s_loc, case.d, n_up * case.fe)) * 0.1,
            jnp.float32)
        wd = jnp.asarray(
            rng.normal(size=(case.s_loc, case.fe, case.d)) * 0.1,
            jnp.float32)
        gp, tg = jnp.asarray(group_pad), jnp.asarray(tile_group)

        def two_pass(x, wu, wd, gp, tg):
            h = grouped_matmul(x, wu, gp, tg, "ragged")
            if case.gated:
                g, u = jnp.split(h, 2, axis=-1)
                h = jax.nn.silu(g) * u
            else:
                h = jax.nn.gelu(h)
            return grouped_matmul(h, wd, gp, tg, "ragged")

        us_two = _time(jax.jit(two_pass), x, wu, wd, gp, tg)
        us_fused = _time(
            lambda *a: fused_expert_ffn_pallas(
                *a, gated=case.gated, tile_k_up=tile_k_up,
                tile_k_dn=tile_k_dn, interpret=True),
            x, wu, wd, tg)

        rows.append((
            f"moe_kernel_{case.name}", us_fused,
            f"us_two_pass={us_two:.1f};tiles={n_tiles};live={n_live};"
            f"fused_bytes={tr['fused']['total']:.0f};"
            f"two_pass_bytes={tr['two_pass']['total']:.0f};"
            f"legacy_bytes={tr['two_pass_legacy']['total']:.0f};"
            f"fused_below={below};"
            f"dma_tiles={dma['dma_tiles']};dma_m_tiles={dma['m_tiles']};"
            f"dma_ok={dma_ok}"))

    # all-dead batch: fused charges zero weight traffic, legacy pays
    tg_dead = np.full(4, -1, np.int64)
    tr_dead = {impl: expert_ffn_traffic(
        impl, d=64, fe=96, n_up=2, tile_m=8, n_tiles=4, live_tiles=0)
        for impl in ("fused", "two_pass_legacy")}
    checks["all_dead"] = (
        tr_dead["fused"]["total"] == 0.0
        and tr_dead["two_pass_legacy"]["total"] > 0.0
        and fused_weight_dma_tiles(tg_dead, 2, 2)["live_tiles"] == 0)
    rows.append(("moe_kernel_all_dead", 0.0,
                 f"fused_bytes=0;legacy_bytes="
                 f"{tr_dead['two_pass_legacy']['total']:.0f};"
                 f"ok={checks['all_dead']}"))
    return rows, checks


def serve_tokens(impl: str, *, algo: str = "metro",
                 use_pallas_route: bool = False,
                 prompt_lens=(5, 9, 3), max_new: int = 4,
                 seed: int = 7) -> dict:
    """Serve a fixed trace on a reduced mixtral engine with the given
    expert datapath; returns {request_id: generated tokens}.  The ONE
    engine-parity harness — tests/test_moe_fused.py imports it too."""
    from repro.configs import get_config
    from repro.core import build_placement, slots_for_ratio
    from repro.models import init_lm
    from repro.serving import EngineConfig, ServingEngine
    from repro.sharding.policy import make_dist

    cfg = get_config("mixtral-8x22b").reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25)
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = build_placement(cfg.num_experts, ep, spd)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert)
    eng = ServingEngine(cfg, dist, params, EngineConfig(
        max_batch=4, max_len=64, moe_impl=impl, decode_algo=algo,
        use_pallas_route=use_pallas_route, rebalance_every=0))
    rng = np.random.default_rng(seed)
    for n in prompt_lens:
        eng.submit(rng.integers(0, cfg.vocab_size, n), max_new)
    eng.run()
    return {rid: tuple(r.generated) for rid, r in eng.completed.items()}


def engine_token_parity(fast: bool = False) -> bool:
    """Serve the same trace with moe_impl="fused" and "ragged" — the
    generated tokens must match (replicated routers, identical routing;
    only the expert datapath changed)."""
    lens = (5, 9) if fast else (5, 9, 3)
    return (serve_tokens("fused", prompt_lens=lens)
            == serve_tokens("ragged", prompt_lens=lens))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--skip-engine", action="store_true",
                    help="skip the (slow) engine token-parity serve")
    args = ap.parse_args()
    rows, checks = run(fast=args.fast)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    assert checks["traffic"], \
        "fused modeled HBM bytes not strictly below two-pass"
    assert checks["dma"], \
        "fused weight-tile DMA count != live tiles (dead-tile skip broken)"
    assert checks["all_dead"], "all-dead accounting broken"
    if not args.skip_engine:
        assert engine_token_parity(fast=args.fast), \
            "engine serve with moe_impl='fused' diverged from 'ragged'"
        print("# engine token parity fused==ragged: OK")
    print("# OK: fused < two-pass modeled traffic on every config; "
          "weight DMA == live tiles")


if __name__ == "__main__":
    main()
