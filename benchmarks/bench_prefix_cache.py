"""Prefix-cache benefit curve: TTFT/TPOT and pages saved vs the
prefix-hit rate, METRO vs EPLB.

Real multi-tenant traffic repeats leading tokens — system prompts,
few-shot preambles, multi-turn sessions.  The shared-prefix KV cache
(``serving/prefix.py``) converts that repetition into skipped prefill
work and deduplicated KV pages; this driver measures how much, on the
full serving stack under deterministic virtual time:

  * **Controlled sweep**: one trace family per sweep point, identical
    arrivals / prompt lengths / output lengths — only
    ``prefix_fraction`` (the share of requests drawing the SHARED
    system prompt rather than a private one of the same length) moves.
    The hit rate is therefore the only independent variable.
  * **Observables** per point: prefix-hit tokens, TTFT mean (plus the
    cached/cold split the SLOTracker separates), TPOT p50, fresh pages
    allocated (``PagedKVManager.alloc_count`` — every page the cache
    did NOT have to re-write), and peak pages-in-use.
  * **Virtual time** (``default_step_cost``): prefill-carrying calls
    charge per token, decode charges the observed ``max_activated`` —
    so skipped prefill tokens shrink TTFT deterministically, and the
    METRO-vs-EPLB decode gap stays visible at every hit rate (the
    cache and the routing algorithm attack different phases; the bench
    shows the benefits compose).

Self-checks (asserted):
  * hit tokens increase monotonically with prefix_fraction;
  * fresh page allocations decrease monotonically (pages saved);
  * TTFT mean decreases monotonically (within a small tolerance for
    scheduling noise at adjacent points);
  * every request completes at every point, for both algorithms.

Run:  PYTHONPATH=src python benchmarks/bench_prefix_cache.py [--fast]
"""
import argparse
import dataclasses

import numpy as np

from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                           TrafficConfig, generate_trace)

try:                                    # python -m benchmarks.run
    from benchmarks.bench_pareto_slo import build_model
except ImportError:                     # direct script invocation
    from bench_pareto_slo import build_model


@dataclasses.dataclass
class PrefixBenchSetup:
    arch: str = "mixtral-8x22b"
    num_replicas: int = 1
    max_batch: int = 8
    max_len: int = 96
    page_size: int = 8
    prefill_chunk: int = 16
    num_requests: int = 40
    arrival_rate: float = 200.0
    prefix_len: int = 40            # shared system-prompt length
    seed: int = 21
    fractions: tuple = (0.0, 0.5, 1.0)
    ttft_tolerance: float = 0.02    # slack for adjacent-point noise


def make_trace(cfg, setup, fraction):
    return generate_trace(TrafficConfig(
        num_requests=setup.num_requests,
        arrival_rate=setup.arrival_rate, seed=setup.seed,
        prompt_len_mean=10, prompt_len_min=4, prompt_len_max=24,
        output_len_mean=6, output_len_sigma=0.3, output_len_max=10,
        vocab_size=cfg.vocab_size,
        prefix_groups=1, prefix_fraction=fraction,
        prefix_len_mean=setup.prefix_len, prefix_len_sigma=0.0,
        prefix_len_min=setup.prefix_len,
        prefix_len_max=setup.prefix_len))


def run_point(cfg, dist, params, setup, algo, fraction, fn_cache):
    ecfg = EngineConfig(
        max_batch=setup.max_batch, max_len=setup.max_len,
        page_size=setup.page_size, prefill_chunk=setup.prefill_chunk,
        decode_algo=algo, rebalance_every=0, enable_prefix_cache=True)
    clus = ClusterEngine(
        cfg, dist, params, ecfg,
        ClusterConfig(num_replicas=setup.num_replicas,
                      dispatch="prefix"),
        fn_cache=fn_cache)
    trace = make_trace(cfg, setup, fraction)
    peak = [0]

    def gauge(c):
        peak[0] = max(peak[0], sum(r.kvman.pages_in_use
                                   for r in c.replicas))

    s = clus.replay_open_loop(trace, on_iteration=gauge)
    s["pages_allocated"] = sum(r.kvman.alloc_count
                               for r in clus.replicas)
    s["pages_peak"] = peak[0]
    s["prefix_hit_tokens"] = sum(
        r.slo.prefix_hit_tokens_total for r in clus.replicas)
    s["ttft_mean"] = float(np.mean(
        [tm.ttft for r in clus.replicas
         for tm in r.slo.timings.values() if tm.finished > 0]))
    for r in clus.replicas:
        r.kvman.check_consistent()
        if r.prefix_index is not None:
            r.prefix_index.check_consistent()
    return s


def run(fast=False, setup=None):
    setup = setup or PrefixBenchSetup()
    if fast:
        setup = dataclasses.replace(setup, num_requests=16)
    cfg, dist, params = build_model(setup)
    rows, checks = [], {"complete": True, "hits_monotone": True,
                        "allocs_monotone": True, "ttft_monotone": True}
    for algo in ("eplb", "metro"):
        fn_cache = {}
        prev = None
        for frac in setup.fractions:
            s = run_point(cfg, dist, params, setup, algo, frac,
                          fn_cache)
            hit_rate = s["prefix_hit_requests"] / max(s["requests"], 1)
            rows.append((
                f"prefix_cache_{algo}_f{int(frac * 100):03d}",
                s["prefix_hit_tokens"],
                f"hit_tokens={s['prefix_hit_tokens']};"
                f"hit_req_rate={hit_rate:.2f};"
                f"ttft_mean={s['ttft_mean'] * 1e3:.3f}ms;"
                f"ttft_p90={s['ttft_p90'] * 1e3:.3f}ms;"
                f"tpot_p50={s['tpot_p50'] * 1e3:.3f}ms;"
                f"pages_alloc={s['pages_allocated']};"
                f"pages_peak={s['pages_peak']};"
                f"requests={s['requests']}"))
            if s["requests"] != setup.num_requests:
                checks["complete"] = False
            if prev is not None:
                if s["prefix_hit_tokens"] < prev["prefix_hit_tokens"]:
                    checks["hits_monotone"] = False
                if s["pages_allocated"] > prev["pages_allocated"]:
                    checks["allocs_monotone"] = False
                if s["ttft_mean"] > prev["ttft_mean"] * \
                        (1 + setup.ttft_tolerance):
                    checks["ttft_monotone"] = False
            prev = s
        # the fully-shared point must actually exercise the cache
        if prev["prefix_hit_tokens"] <= 0:
            checks["hits_monotone"] = False
    return rows, checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    rows, checks = run(fast=args.fast)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")
    assert checks["complete"], "a sweep point dropped requests"
    assert checks["hits_monotone"], \
        "prefix-hit tokens did not rise with the shared fraction"
    assert checks["allocs_monotone"], \
        "fresh page allocations did not fall with the hit rate"
    assert checks["ttft_monotone"], \
        "TTFT did not fall with the hit rate"
    print("# OK: hit tokens up, fresh pages down, TTFT down as the "
          "shared fraction rises (METRO and EPLB)")


if __name__ == "__main__":
    main()
