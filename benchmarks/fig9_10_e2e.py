"""Figs. 9/10: end-to-end total token throughput + decode latency (TPOT)
for METRO vs EPLB routing across models, workloads, replication ratios.

Fig. 9 analogue: A100-class hardware model, Qwen3-30B (the paper's
real-system testbed).  Fig. 10 analogue: B200 hardware model,
Qwen3-235B (8 ranks) and DeepSeek-V3 (16 ranks).  Decode-heavy
(humaneval/instructcoder-like, skewed experts) and prefill-heavy
(gsm8k-like) workloads.
"""
from repro.configs import get_config
from repro.core.metrics import A100_40G, B200
from repro.sim import ParallelismConfig, WorkloadConfig, simulate_serving

SETUPS = [
    # (fig, model, hw, ep, decode_batch, n_req)
    ("fig9", "qwen3-30b-a3b", A100_40G, 8, 256, 32),
    ("fig10", "qwen3-235b-a22b", B200, 8, 1024, 64),
    ("fig10", "deepseek-v3-671b", B200, 16, 1024, 64),
]
WORKLOADS = [
    WorkloadConfig("decodeheavy", zipf_alpha=1.2, prompt_len=1024,
                   gen_len=2048),
    WorkloadConfig("prefillheavy", zipf_alpha=0.8, prompt_len=4096,
                   gen_len=256),
]


def run(ratios=(1.0, 1.125, 1.25, 1.5)):
    rows = []
    for fig, model, hw, ep, dbatch, nreq in SETUPS:
        cfg = get_config(model)
        par = ParallelismConfig(tp=1, ep=ep)
        for wl in WORKLOADS:
            base = {}
            for ratio in ratios:
                for algo in ("eplb", "metro"):
                    r = simulate_serving(
                        cfg, hw, par, wl, algo=algo,
                        replication_ratio=ratio, decode_batch=dbatch,
                        n_requests=nreq,
                        seed=hash((model, wl.name)) % 2**31)
                    key = (ratio, algo)
                    base[key] = r
                    if algo == "metro" and (ratio, "eplb") in base:
                        e = base[(ratio, "eplb")]
                        dt = -100 * (1 - r["tpot_s"] / e["tpot_s"])
                        dthr = 100 * (r["total_token_throughput"]
                                      / e["total_token_throughput"] - 1)
                        derived = (f"tpot_vs_eplb={dt:+.1f}%;"
                                   f"tput_vs_eplb={dthr:+.1f}%;"
                                   f"act={r['max_activated']}vs"
                                   f"{e['max_activated']}")
                    else:
                        derived = (f"tput={r['total_token_throughput']:.0f};"
                                   f"act={r['max_activated']}")
                    rows.append((
                        f"{fig}_{model}_{wl.name}_r{ratio}_{algo}",
                        r["tpot_s"] * 1e6, derived))
    return rows
