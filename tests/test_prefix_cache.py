"""Shared-prefix KV cache lockdown: radix index, refcounted/CoW pages,
page-aware admission, and the bit-exact hit == cold equivalence.

The subsystem's one non-negotiable claim: serving a request through a
prefix hit changes *nothing* observable about that request — generated
tokens AND the logical KV its pages hold are bitwise identical to the
same request served cold, for every supported mixer type (full
attention and SWA; mamba-bearing archs auto-disable, their SSM state is
not paged), including after preemption/recompute while the request
holds shared + copy-on-write pages.  KV content for a (token sequence,
position) is deterministic — independent of batch composition, chunk
split, and physical page id — which is what makes reuse and
content-dedup safe; these tests pin it end to end.

Fast half: allocator refcount/pin/index mechanics, the radix index
(match/insert/dedup/partial-tail/LRU eviction), a hypothesis fuzz of
the admit/share/release/evict lifecycle with ``check_consistent`` after
every op, SLO prefix attribution, shared-prefix traffic generation, and
the fp8-vs-fp32 paged-attention parity (tolerance-based).

Slow half: engine-level equivalences (jit full model steps).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.models import layers as L
from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                           PagedKVManager, RadixPrefixIndex,
                           ServingEngine, SLOTracker, TrafficConfig,
                           generate_trace)
from repro.serving.kv import pages_for
from repro.sharding.policy import make_dist

PS = 4


def _man(num_pages=16, max_seqs=4, mpps=8):
    return PagedKVManager(num_pages=num_pages, page_size=PS,
                          max_pages_per_seq=mpps, max_seqs=max_seqs)


# ======================================================================
# fast: allocator sharing mechanics
# ======================================================================


@pytest.mark.fast
class TestManagerSharing:
    def test_map_shared_refcounts_and_release_order(self):
        m = _man()
        assert m.ensure(0, 9)               # 3 pages, refcount 1 each
        pages = [int(p) for p in m.page_table[0, :3]]
        m.map_shared(1, pages[:2])          # slot 1 shares 2 of them
        assert (m.refcount[pages[:2]] == 2).all()
        assert m.refcount[pages[2]] == 1
        m.check_consistent()
        # releasing the original keeps the shared pages alive
        freed = m.release(0)
        assert freed == 1                   # only the unshared page
        assert (m.refcount[pages[:2]] == 1).all()
        m.check_consistent()
        assert m.release(1) == 2
        assert m.num_free == m.num_pages
        m.check_consistent()

    def test_indexed_pages_survive_release_and_unindex_frees(self):
        m = _man()
        assert m.ensure(0, 8)
        pages = [int(p) for p in m.page_table[0, :2]]
        for p in pages:
            m.index_page(p)
        assert m.release(0) == 0            # index holds both
        assert m.num_reclaimable == 2
        m.check_consistent()
        assert m.unindex_page(pages[0])     # goes free now
        assert m.num_free == m.num_pages - 1
        m.check_consistent()

    def test_pin_blocks_free_until_unpin(self):
        m = _man()
        assert m.ensure(0, 4)
        p = int(m.page_table[0, 0])
        m.index_page(p)
        m.pin(p)
        m.release(0)
        assert not m.unindex_page(p)        # pinned: stays off free list
        assert m.num_reclaimable == 0
        m.check_consistent()
        m.unpin(p)                          # last reference drops
        assert m.num_free == m.num_pages
        m.check_consistent()

    def test_shared_growth_allocates_private_tail(self):
        """A slot seeded with shared pages grows with fresh pages above
        them (ensure never touches the shared prefix)."""
        m = _man()
        assert m.ensure(0, 8)
        shared = [int(p) for p in m.page_table[0, :2]]
        for p in shared:
            m.index_page(p)
        m.release(0)
        m.map_shared(1, shared)
        assert m.ensure(1, 16)              # 2 shared + 2 private
        tail = [int(p) for p in m.page_table[1, 2:4]]
        assert not set(tail) & set(shared)
        assert (m.refcount[tail] == 1).all()
        m.check_consistent()

    def test_check_consistent_catches_refcount_drift(self):
        m = _man()
        assert m.ensure(0, 4)
        m.refcount[int(m.page_table[0, 0])] += 1
        with pytest.raises(AssertionError):
            m.check_consistent()


# ======================================================================
# fast: radix index
# ======================================================================


def _serve_and_insert(man, idx, tokens, slot):
    """Mimic one admission+retire lifecycle at the bookkeeping level:
    match, share, CoW-pin, allocate the suffix, insert, release."""
    tokens = np.asarray(tokens)
    match = idx.match(tokens)
    idx.touch(match)
    man.map_shared(slot, match.pages)
    if match.cow_src is not None:
        man.pin(match.cow_src)
    need = pages_for(len(tokens), PS) - man.owned(slot)
    if need > man.num_free:
        idx.reclaim(need - man.num_free)
    ok = man.ensure(slot, len(tokens))
    if match.cow_src is not None:
        man.unpin(match.cow_src)
    if not ok:                              # pool genuinely too small
        man.release(slot)
        return None
    pages = [int(man.page_table[slot, i])
             for i in range(pages_for(len(tokens), PS))]
    idx.insert(tokens, pages)
    man.release(slot)
    return match


@pytest.mark.fast
class TestRadixIndex:
    def _fresh(self, num_pages=32):
        man = _man(num_pages=num_pages, max_seqs=4, mpps=num_pages)
        return man, RadixPrefixIndex(man, PS)

    def test_exact_reinsert_dedupes_everything(self):
        man, idx = self._fresh()
        seq = np.arange(10) % 7
        _serve_and_insert(man, idx, seq, 0)
        before = idx.cached_pages()
        m = _serve_and_insert(man, idx, seq, 1)
        assert m.m == 10                    # full hit (partial tail CoW)
        assert m.cow_src is not None        # 10 % 4 != 0
        assert len(m.pages) == 2
        assert idx.cached_pages() == before  # nothing new indexed
        idx.check_consistent(), man.check_consistent()

    def test_page_aligned_match_has_no_cow(self):
        man, idx = self._fresh()
        seq = np.arange(8)
        _serve_and_insert(man, idx, seq, 0)
        m = idx.match(np.concatenate([seq, [99, 98]]))
        assert m.m == 8 and m.cow_src is None and len(m.pages) == 2

    def test_token_level_partial_match_inside_a_page(self):
        man, idx = self._fresh()
        seq = np.array([1, 2, 3, 4, 5, 6, 7, 8])
        _serve_and_insert(man, idx, seq, 0)
        m = idx.match(np.array([1, 2, 3, 4, 5, 6, 99, 98]))
        assert m.m == 6                     # 4 full + 2 into page 2
        assert len(m.pages) == 1 and m.cow_src is not None

    def test_divergent_siblings_and_best_match(self):
        man, idx = self._fresh()
        a = np.array([1, 2, 3, 4, 10, 11, 12, 13])
        b = np.array([1, 2, 3, 4, 10, 11, 77, 88])
        _serve_and_insert(man, idx, a, 0)
        mb = _serve_and_insert(man, idx, b, 1)
        assert mb.m == 6                    # shared page + 2 tokens CoW
        # now both second pages are cached as siblings; the better one
        # wins for each probe
        assert idx.match(a).m == 8
        assert idx.match(b).m == 8
        assert idx.match(np.array([1, 2, 3, 4, 10, 11, 77, 0])).m == 7
        idx.check_consistent()

    def test_shorter_reinsert_is_subsumed_by_longer(self):
        """Retiring a short request whose tail is a strict prefix of an
        already-cached longer page must not pin a redundant page (the
        longer node serves every match the short one could)."""
        man, idx = self._fresh()
        long = np.array([5, 6, 7, 8, 9, 10, 11, 12])
        _serve_and_insert(man, idx, long, 0)
        assert idx.cached_pages() == 2
        _serve_and_insert(man, idx, long[:6], 1)    # tail = [9, 10]
        assert idx.cached_pages() == 2              # nothing new pinned
        assert idx.match(long[:6]).m == 6           # still fully served
        idx.check_consistent(), man.check_consistent()

    def test_longer_insert_subsumes_partial_tail(self):
        man, idx = self._fresh()
        short = np.array([5, 6, 7, 8, 9, 10])        # partial tail of 2
        _serve_and_insert(man, idx, short, 0)
        assert idx.cached_pages() == 2
        longer = np.array([5, 6, 7, 8, 9, 10, 11, 12])
        _serve_and_insert(man, idx, longer, 1)
        # the 2-token partial leaf was subsumed by the full page
        assert idx.cached_pages() == 2
        assert idx.match(longer).m == 8
        idx.check_consistent(), man.check_consistent()

    def test_lru_reclaim_evicts_oldest_leaf_first(self):
        man, idx = self._fresh()
        a = np.array([1, 1, 1, 1, 2, 2])
        b = np.array([3, 3, 3, 3, 4, 4])
        _serve_and_insert(man, idx, a, 0)
        _serve_and_insert(man, idx, b, 1)
        idx.touch(idx.match(a))             # a is now more recent
        assert idx.reclaim(1) == 1
        assert idx.match(b).m < 6           # b's tail died first
        assert idx.match(a).m == 6
        idx.check_consistent(), man.check_consistent()

    def test_reclaim_skips_pages_shared_by_active_slots(self):
        man, idx = self._fresh()
        seq = np.arange(8)
        _serve_and_insert(man, idx, seq, 0)
        m = idx.match(seq)
        man.map_shared(2, m.pages)          # an active request shares
        assert idx.reclaim(10) == 0         # nothing evictable
        assert idx.match(seq).m == 8
        man.release(2)
        assert idx.reclaim(10) == 2         # now both go
        assert idx.match(seq).m == 0
        idx.check_consistent(), man.check_consistent()


# ======================================================================
# fast: hypothesis fuzz of the admit/share/release/evict lifecycle
# ======================================================================


@pytest.mark.fast
class TestRefcountFuzz:
    def test_lifecycle_invariants_hold_under_random_ops(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(st.data())
        @settings(deadline=None)
        def prop(data):
            man = _man(num_pages=12, max_seqs=3, mpps=12)
            idx = RadixPrefixIndex(man, PS)
            live = []                       # slots mid-lifecycle
            n_ops = data.draw(st.integers(5, 40))
            for _ in range(n_ops):
                free_slots = [s for s in range(3) if s not in live]
                ops = ["serve", "reclaim"]
                if free_slots:
                    ops.append("admit")
                if live:
                    ops.append("drop")
                op = data.draw(st.sampled_from(ops))
                if op == "serve" and free_slots:
                    # full lifecycle in one go (admission -> retire)
                    n = data.draw(st.integers(1, 16))
                    seq = data.draw(st.lists(st.integers(0, 3),
                                             min_size=n, max_size=n))
                    _serve_and_insert(man, idx, np.asarray(seq),
                                      free_slots[0])
                elif op == "admit" and free_slots:
                    # admission that stays active (holds refs)
                    n = data.draw(st.integers(1, 12))
                    seq = np.asarray(data.draw(st.lists(
                        st.integers(0, 3), min_size=n, max_size=n)))
                    s = free_slots[0]
                    m = idx.match(seq)
                    man.map_shared(s, m.pages)
                    need = pages_for(n, PS) - man.owned(s)
                    if need > man.num_free:
                        idx.reclaim(need - man.num_free)
                    if man.ensure(s, n):
                        live.append(s)
                    else:
                        man.release(s)
                elif op == "drop" and live:
                    man.release(live.pop(
                        data.draw(st.integers(0, len(live) - 1))))
                elif op == "reclaim":
                    idx.reclaim(data.draw(st.integers(1, 6)))
                man.check_consistent()
                idx.check_consistent()
            # full teardown drains everything
            for s in live:
                man.release(s)
            idx.reclaim(man.num_pages)
            assert man.num_free == man.num_pages
            man.check_consistent(), idx.check_consistent()

        prop()


# ======================================================================
# fast: SLO prefix attribution
# ======================================================================


@pytest.mark.fast
class TestSLOPrefixAttribution:
    def test_hit_and_cold_ttft_separable(self):
        class Clock:
            t = 0.0

            def __call__(self):
                return self.t

        clk = Clock()
        slo = SLOTracker(clock=clk)
        slo.arrive(0, 20)                   # cold request
        slo.arrive(1, 20)                   # cached request
        slo.admitted(0), slo.admitted(1)
        slo.prefix_hit(1, 16)
        clk.t = 2.0
        slo.first_token(0)
        clk.t = 0.5
        slo.first_token(1)
        slo.finish(0), slo.finish(1)
        s = slo.summary()
        assert s["prefix_hit_tokens"] == 16
        assert s["prefix_hit_requests"] == 1
        assert s["ttft_mean_cold"] == pytest.approx(2.0)
        assert s["ttft_mean_hit"] == pytest.approx(0.5)

    def test_recompute_hits_count_tokens_but_keep_first_attribution(self):
        t = [0.0]
        slo = SLOTracker(clock=lambda: t[0])
        slo.arrive(0, 8)
        slo.prefix_hit(0, 8)
        t[0] = 1.0
        slo.first_token(0)
        slo.prefix_hit(0, 5)        # post-first-token readmission
        t[0] = 2.0
        slo.finish(0)
        s = slo.summary()
        assert s["prefix_hit_tokens"] == 13     # savings both times
        assert slo.timings[0].n_prefix_hit == 8  # TTFT split frozen

    def test_cold_readmission_resets_the_hit_split(self):
        """Hit, preempted, readmitted COLD (cache since evicted): the
        request must land in the cold TTFT population — the scheduler
        stamps prefix_hit(0) on every cache-enabled admission."""
        t = [0.0]
        slo = SLOTracker(clock=lambda: t[0])
        slo.arrive(0, 8)
        slo.prefix_hit(0, 8)        # first admission: hit
        slo.prefix_hit(0, 0)        # readmission: miss, pre-first-token
        t[0] = 1.0
        slo.first_token(0)
        t[0] = 2.0
        slo.finish(0)
        s = slo.summary()
        assert s["prefix_hit_tokens"] == 8      # the avoided work was real
        assert s["prefix_hit_requests"] == 0    # but TTFT counts as cold
        assert s["ttft_mean_cold"] == pytest.approx(1.0)


# ======================================================================
# fast: shared-prefix traffic generation
# ======================================================================


@pytest.mark.fast
class TestSharedPrefixTraffic:
    def test_fraction_sweep_changes_only_sharing(self):
        """The controlled variable: prefix_fraction must not move
        arrivals, prompt lengths, or output lengths — only whether the
        prefix tokens are shared."""
        base = TrafficConfig(num_requests=40, prefix_groups=2, seed=3)
        t0 = generate_trace(
            base.__class__(**{**base.__dict__, "prefix_fraction": 0.0}))
        t1 = generate_trace(
            base.__class__(**{**base.__dict__, "prefix_fraction": 1.0}))
        assert [r.arrival for r in t0] == [r.arrival for r in t1]
        assert [len(r.prompt) for r in t0] == [len(r.prompt) for r in t1]
        assert [r.max_new_tokens for r in t0] == \
            [r.max_new_tokens for r in t1]
        # full sharing: every prompt starts with one of 2 group prefixes
        firsts = {tuple(r.prompt[:8]) for r in t1}
        assert len(firsts) <= 2
        # no sharing: private prefixes are (overwhelmingly) distinct
        assert len({tuple(r.prompt[:8]) for r in t0}) > 10

    def test_multi_turn_chains_are_prompt_prefixes(self):
        tcfg = TrafficConfig(num_requests=40, prefix_groups=2,
                             turns_max=3, turn_continue_p=0.7, seed=5)
        trace = generate_trace(tcfg)
        chains = 0
        for j in range(len(trace)):
            for i in range(j):
                pi, pj = trace[i].prompt, trace[j].prompt
                if len(pj) > len(pi) and (pj[:len(pi)] == pi).all():
                    chains += 1
                    break
        assert chains > 0

    def test_off_switch_is_bit_identical(self):
        a = generate_trace(TrafficConfig(num_requests=16, seed=9))
        b = generate_trace(TrafficConfig(num_requests=16, seed=9,
                                         prefix_len_mean=99.0))
        assert all((x.prompt == y.prompt).all()
                   and x.arrival == y.arrival for x, y in zip(a, b))


# ======================================================================
# fast: fp8 KV pool parity (op level, tolerance-based)
# ======================================================================


@pytest.mark.fast
class TestFp8PagedParity:
    def test_decode_fp8_pool_matches_fp32_pool(self):
        cfg = get_config("mixtral-8x22b").reduced()
        dims = L.attn_dims(cfg, 4)
        rng = np.random.default_rng(0)
        params = L.init_attention(cfg, jax.random.PRNGKey(0), tp=4)
        b, ps, pmax = 2, 8, 3
        num_pages = b * pmax
        x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)) * 0.3,
                        jnp.float32)
        k_pool = rng.normal(size=(num_pages, ps, dims.kv,
                                  dims.head_dim)).astype(np.float32) * 0.3
        v_pool = rng.normal(size=k_pool.shape).astype(np.float32) * 0.3
        pt = np.arange(num_pages, dtype=np.int32).reshape(b, pmax)
        pos = jnp.asarray([13, 20], jnp.int32)
        outs = {}
        for name, dt in (("fp32", jnp.float32),
                         ("fp8", jnp.float8_e4m3fn)):
            cache = {"k": jnp.asarray(k_pool).astype(dt),
                     "v": jnp.asarray(v_pool).astype(dt)}
            o, _ = L.attention_decode_paged(
                cfg, params, x, cache, jnp.asarray(pt), pos, dims=dims)
            outs[name] = np.asarray(o, np.float32)
        scale = np.abs(outs["fp32"]).max()
        assert np.abs(outs["fp8"] - outs["fp32"]).max() < 0.25 * scale
        # and they are genuinely close in aggregate
        assert np.abs(outs["fp8"] - outs["fp32"]).mean() < 0.05 * scale


# ======================================================================
# slow: engine-level equivalence
# ======================================================================


_SETUP_CACHE: dict = {}


def _setup(name):
    if name not in _SETUP_CACHE:
        cfg = get_config(name).reduced()
        ep = 4
        spd = slots_for_ratio(cfg.num_experts, ep, 1.25) \
            if cfg.is_moe else 1
        dist = make_dist(None, ep_size=ep, slots_per_device=spd)
        placement = (build_placement(cfg.num_experts, ep, spd)
                     if cfg.is_moe else None)
        params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                         replica_expert=placement.replica_expert
                         if placement else None)
        _SETUP_CACHE[name] = (cfg, dist, params)
    return _SETUP_CACHE[name]


def _engine(name, **kw):
    cfg, dist, params = _setup(name)
    ecfg = EngineConfig(**{"max_batch": 4, "max_len": 64, "page_size": 8,
                           "prefill_chunk": 8, "rebalance_every": 0,
                           **kw})
    return cfg, ServingEngine(cfg, dist, params, ecfg)


def _logical_kv(eng, slot, n_pages):
    """A request's logical KV content, gathered page-table order —
    physical page ids are scheduling, content is semantics."""
    pt = eng.kvman.page_table[slot]
    out = []
    for li, pool in eng.cache.items():
        if "conv" in pool:
            continue
        for key in ("k", "v"):
            arr = np.asarray(pool[key])
            for lp in pt[:n_pages]:
                assert lp >= 0
                out.append(arr[:, lp])
    return out


# the two attention mixer families the prefix cache supports: pure
# full-attention MoE and the SWA+full interleave
ARCHS = ["mixtral-8x22b", "gemma3-12b"]


@pytest.mark.slow
class TestHitEqualsCold:
    @pytest.mark.parametrize("name", ARCHS)
    def test_identical_prompt_full_hit_bitexact(self, name):
        """Second serving of an identical prompt: full-context hit (no
        prefill at all), tokens AND logical KV bitwise equal to cold."""
        cfg, cold = _engine(name)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size, 19)

        def run_and_capture(eng, gen=6):
            rid = eng.submit(prompt, gen)
            # step until the request has produced 2 tokens, capture its
            # logical KV mid-flight (pages are released at retire)
            while True:
                req = eng.active.get(rid)
                if req is not None and len(req.generated) >= 2:
                    break
                eng.step()
            r = eng.active[rid]
            kv = _logical_kv(eng, r.slot, pages_for(r.n_ctx, 8))
            eng.run()
            return tuple(eng.completed[rid].generated), kv

        toks_cold, kv_cold = run_and_capture(cold)

        _, warm = _engine(name, enable_prefix_cache=True)
        assert warm.prefix_enabled
        warm.submit(prompt, 6)
        warm.run()
        toks_first = tuple(warm.completed[0].generated)
        toks_hit, kv_hit = run_and_capture(warm)
        r2 = warm.completed[1]
        assert r2.prefix_hit_tokens == 19       # full-context hit
        assert toks_first == toks_cold
        assert toks_hit == toks_cold
        assert len(kv_hit) == len(kv_cold) > 0
        for a, b in zip(kv_hit, kv_cold):
            np.testing.assert_array_equal(a, b)
        warm.kvman.check_consistent()
        warm.prefix_index.check_consistent()

    @pytest.mark.parametrize("name", ARCHS)
    def test_extended_prompt_partial_hit_bitexact(self, name):
        """Multi-turn shape: new prompt extends a cached one — full
        shared pages + one token-level CoW boundary page."""
        cfg, cold = _engine(name)
        rng = np.random.default_rng(1)
        head = rng.integers(0, cfg.vocab_size, 19)      # 19 % 8 != 0
        full = np.concatenate([head,
                               rng.integers(0, cfg.vocab_size, 14)])
        cold.submit(full, 6)
        cold.run()
        toks_cold = tuple(cold.completed[0].generated)

        _, warm = _engine(name, enable_prefix_cache=True)
        warm.submit(head, 4)
        warm.run()
        rid = warm.submit(full, 6)
        warm.run()
        r = warm.completed[rid]
        assert r.prefix_hit_tokens == 19        # head fully reused
        assert tuple(r.generated) == toks_cold
        warm.kvman.check_consistent()
        warm.prefix_index.check_consistent()

    def test_shorter_prompt_mid_page_hit_bitexact(self):
        """Prompt that ends inside a cached page: every matched token
        comes through the CoW copy."""
        cfg, cold = _engine("mixtral-8x22b")
        rng = np.random.default_rng(2)
        long = rng.integers(0, cfg.vocab_size, 30)
        short = long[:11]                       # mid-page (11 % 8 != 0)
        cold.submit(short, 6)
        cold.run()
        toks_cold = tuple(cold.completed[0].generated)

        _, warm = _engine("mixtral-8x22b", enable_prefix_cache=True)
        warm.submit(long, 4)
        warm.run()
        rid = warm.submit(short, 6)
        warm.run()
        r = warm.completed[rid]
        assert r.prefix_hit_tokens == 11
        assert tuple(r.generated) == toks_cold

    @pytest.mark.parametrize("algo", ["metro", "eplb"])
    def test_staggered_mixed_trace_on_equals_off(self, algo):
        """Hits admitted while other rows decode (mixed steps): every
        request's tokens are identical with the cache on and off — the
        cache changes scheduling and memory, never numerics."""
        cfg, _ = _engine("mixtral-8x22b")
        rng = np.random.default_rng(3)
        sys_p = rng.integers(0, cfg.vocab_size, 17)
        prompts = []
        for i in range(6):
            sfx = rng.integers(0, cfg.vocab_size, 5 + 3 * i)
            prompts.append(np.concatenate([sys_p, sfx])
                           if i % 2 == 0 else sfx)

        def serve(**kw):
            _, e = _engine("mixtral-8x22b", decode_algo=algo, **kw)
            it = iter(prompts)
            e.submit(next(it), 6)
            k = 0
            while e.has_work:
                e.step()
                k += 1
                if k % 2 == 0:
                    nxt = next(it, None)
                    if nxt is not None:
                        e.submit(nxt, 6)
            for nxt in it:
                e.submit(nxt, 6)
                e.run()
            return e

        off = serve()
        on = serve(enable_prefix_cache=True)
        assert len(on.completed) == len(prompts)
        for rid in off.completed:
            assert tuple(on.completed[rid].generated) == \
                tuple(off.completed[rid].generated)
        assert on.slo.summary()["prefix_hit_tokens"] > 0
        on.kvman.check_consistent()
        on.prefix_index.check_consistent()

    def test_mamba_archs_auto_disable_and_still_serve(self):
        for name in ("falcon-mamba-7b", "jamba-1.5-large-398b"):
            cfg, eng = _engine(name, enable_prefix_cache=True)
            assert not eng.prefix_enabled
            assert eng.prefix_index is None
            rng = np.random.default_rng(4)
            eng.submit(rng.integers(0, cfg.vocab_size, 12), 4)
            eng.run()
            assert len(eng.completed) == 1


@pytest.mark.slow
class TestPreemptionWithCoW:
    def test_preempt_holding_shared_and_cow_pages_recomputes_bitexact(
            self):
        """The acceptance case: a prefix-hit request evicted between
        suffix chunks — its shared references drop, its CoW page frees,
        readmission re-matches and recomputes to exactly the cold run's
        tokens, with allocator+index invariants intact throughout."""
        cfg, cold = _engine("mixtral-8x22b")
        rng = np.random.default_rng(5)
        prompt = rng.integers(0, cfg.vocab_size, 30)
        cold.submit(prompt, 8)
        cold.run()
        toks_cold = tuple(cold.completed[0].generated)

        _, warm = _engine("mixtral-8x22b", enable_prefix_cache=True)
        warm.submit(prompt[:19], 4)
        warm.run()
        rid = warm.submit(prompt, 8)
        warm.step()                 # hit=19, first suffix chunk runs
        req = warm.active[rid]
        assert req.prefix_hit_tokens == 19
        assert req.prefilling       # genuinely mid-suffix-prefill
        cached_before = warm.prefix_index.cached_pages()
        assert warm._preempt_one(protect_rid=-1)
        assert req.rid not in warm.active
        # shared pages survived in the index; private pages freed
        assert warm.prefix_index.cached_pages() == cached_before
        warm.kvman.check_consistent()
        warm.prefix_index.check_consistent()
        warm.run()
        r = warm.completed[rid]
        assert r.preempted == 1 and r.preempted_in_prefill == 1
        assert r.prefix_hit_tokens == 19        # re-hit on readmission
        assert tuple(r.generated) == toks_cold
        warm.kvman.check_consistent()
        warm.prefix_index.check_consistent()

    def test_natural_pressure_with_cache_completes_and_stays_sound(self):
        """Tight pool + hot cache: reclaim-before-preempt keeps every
        request finishing with full token counts and invariants held."""
        cfg, eng = _engine("mixtral-8x22b", enable_prefix_cache=True,
                           num_pages=24, page_size=4, max_len=64)
        rng = np.random.default_rng(6)
        sys_p = rng.integers(0, cfg.vocab_size, 13)
        for i in range(5):
            sfx = rng.integers(0, cfg.vocab_size, 6 + 4 * i)
            eng.submit(np.concatenate([sys_p, sfx]), 8)
        eng.run()
        assert len(eng.completed) == 5
        assert all(len(r.generated) == 8 for r in eng.completed.values())
        assert eng.slo.summary()["prefix_hit_tokens"] > 0
        eng.kvman.check_consistent()
        eng.prefix_index.check_consistent()


@pytest.mark.slow
class TestPageAwareAdmission:
    def test_admission_reclaims_cache_instead_of_deferring(self):
        """need > free but need <= free + reclaimable: the policy admits
        by evicting LRU prefix pages."""
        cfg, eng = _engine("mixtral-8x22b", enable_prefix_cache=True,
                           max_len=32, page_size=8, num_pages=4,
                           prefill_chunk=16)
        rng = np.random.default_rng(7)
        eng.submit(rng.integers(0, cfg.vocab_size, 24), 4)
        eng.run()
        assert eng.prefix_index.cached_pages() == 3
        assert eng.kvman.num_free == 1
        rid = eng.submit(rng.integers(0, cfg.vocab_size, 30), 2)
        admitted = eng._admit()
        assert [r.rid for r in admitted] == [rid]
        assert eng.prefix_index.evicted_pages >= 1
        eng.kvman.check_consistent()
        eng.prefix_index.check_consistent()
        eng.run()
        assert len(eng.completed) == 2

    def test_hit_needs_fewer_fresh_pages_than_cold(self):
        """The suffix-after-match term: a request whose first chunk is
        fully covered by cached pages admits where a cold one defers."""
        cfg, eng = _engine("mixtral-8x22b", enable_prefix_cache=True,
                           max_len=32, page_size=8, num_pages=4,
                           prefill_chunk=16)
        rng = np.random.default_rng(8)
        prompt = rng.integers(0, cfg.vocab_size, 24)
        eng.submit(prompt, 4)
        eng.run()
        # pin the cache by keeping a third request active on its pages
        # -> occupy all free pages with an active long request
        blocker = eng.submit(rng.integers(0, cfg.vocab_size, 7), 24)
        eng.step()              # blocker active: 1 page, free=0
        assert eng.kvman.num_free == 0
        cold_r = eng.state.new_request(
            rng.integers(0, cfg.vocab_size, 20), 2)
        plan_cold = eng.sched.plan_admission(cold_r, qdepth=1)
        hit_r = eng.state.new_request(prompt[:20], 2)
        plan_hit = eng.sched.plan_admission(hit_r, qdepth=1)
        # cold needs 2 fresh pages it can only get by evicting the
        # cache the hit request needs; the hit needs at most the CoW page
        assert plan_cold.need > plan_hit.need

    def test_reserve_frac_defers_shallow_queue_admits_deep(self):
        """The queue-depth term: headroom holds a request back when the
        queue is shallow and decays away under backlog."""
        cfg, eng = _engine("mixtral-8x22b", max_len=32, page_size=8,
                           num_pages=5, prefill_chunk=8,
                           admit_reserve_frac=2.0)
        rng = np.random.default_rng(9)
        r = eng.state.new_request(rng.integers(0, cfg.vocab_size, 20),
                                  11)
        # expected total 32 tokens = 4 pages, first chunk 1 page ->
        # future = 3; frac/(1+q): q=0 -> hold 6 > budget 5 -> defer
        assert eng.sched.plan_admission(r, qdepth=0).decision == "defer"
        assert eng.sched.plan_admission(r, qdepth=9).decision == "admit"

    def test_default_policy_matches_pr2_first_chunk_gate(self):
        """admit_reserve_frac=0 + no cache is exactly the old gate
        (regression: the PR-2 skip-ahead suite also pins this)."""
        cfg, eng = _engine("mixtral-8x22b", num_pages=8, max_len=64,
                           prefill_chunk=32)
        assert eng.kvman.ensure(3, 48)      # 6 of 8 pages gone
        eng.free_slots.remove(3)
        rng = np.random.default_rng(0)
        rid_long = eng.submit(rng.integers(0, cfg.vocab_size, 40), 4)
        rid_short = eng.submit(rng.integers(0, cfg.vocab_size, 10), 4)
        admitted = eng._admit()
        assert [r.rid for r in admitted] == [rid_short]
        assert [r.rid for r in eng.queue] == [rid_long]


@pytest.mark.slow
class TestFp8Engine:
    def test_fp8_pool_serves_and_halves_kv_bytes(self):
        cfg, eng = _engine("mixtral-8x22b", kv_dtype="fp8")
        rng = np.random.default_rng(10)
        eng.submit(rng.integers(0, cfg.vocab_size, 20), 5)
        eng.run()
        assert len(eng.completed) == 1
        assert len(eng.completed[0].generated) == 5
        k = next(v for li, v in eng.cache.items() if "k" in v)["k"]
        assert jnp.dtype(k.dtype).itemsize == 1

    def test_fp8_with_prefix_cache_hits_consistently(self):
        """Quantized pools reuse bit-identically too: the cached pages
        ARE the fp8 bits, so a hit replays exactly what cold wrote."""
        cfg, eng = _engine("mixtral-8x22b", kv_dtype="fp8",
                           enable_prefix_cache=True)
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, cfg.vocab_size, 19)
        eng.submit(prompt, 5)
        eng.run()
        first = tuple(eng.completed[0].generated)
        rid = eng.submit(prompt, 5)
        eng.run()
        r = eng.completed[rid]
        assert r.prefix_hit_tokens == 19
        assert tuple(r.generated) == first


@pytest.mark.slow
class TestPrefixDispatch:
    def test_single_replica_prefix_dispatch_equals_bare_engine(self):
        """PR-3 determinism with the new dispatch + cache on: the
        cluster layer still adds no numerics."""
        cfg, dist, params = _setup("mixtral-8x22b")
        ecfg = EngineConfig(max_batch=4, max_len=64, page_size=8,
                            prefill_chunk=8, rebalance_every=0,
                            enable_prefix_cache=True)
        rng = np.random.default_rng(12)
        sys_p = rng.integers(0, cfg.vocab_size, 17)
        prompts = [np.concatenate(
            [sys_p, rng.integers(0, cfg.vocab_size, 4 + 3 * i)])
            for i in range(4)]

        bare = ServingEngine(cfg, dist, jax.tree.map(lambda a: a, params),
                             ecfg)
        for p in prompts:
            bare.submit(p, 5)
        bare.run()

        clus = ClusterEngine(cfg, dist, params, ecfg,
                             ClusterConfig(num_replicas=1,
                                           dispatch="prefix"),
                             step_cost=None)
        for p in prompts:
            clus.submit(p, 5)
        clus.run()
        assert len(clus.completed) == len(prompts)
        for rid, r in bare.completed.items():
            assert tuple(clus.completed[rid].generated) == \
                tuple(r.generated)
        hb, hc = bare.expert_hist_log, clus.replicas[0].expert_hist_log
        assert len(hb) == len(hc) > 0
        for a, b in zip(hb, hc):
            np.testing.assert_array_equal(a, b)

    def test_two_replica_affinity_routes_to_the_warm_cache(self):
        cfg, dist, params = _setup("mixtral-8x22b")
        # prefix_min_tokens=8: incidental 1-2 token matches of random
        # prompts must not steer dispatch (admission wouldn't take them)
        ecfg = EngineConfig(max_batch=4, max_len=64, page_size=8,
                            prefill_chunk=8, rebalance_every=0,
                            enable_prefix_cache=True,
                            prefix_min_tokens=8)
        clus = ClusterEngine(cfg, dist, params, ecfg,
                             ClusterConfig(num_replicas=2,
                                           dispatch="prefix"),
                             step_cost=None)
        rng = np.random.default_rng(13)
        prompt = rng.integers(0, cfg.vocab_size, 19)
        c0 = clus.submit(prompt, 4)
        home = clus.replica_of(c0)
        clus.run()
        # the warm replica wins the rematch even though both are idle
        c1 = clus.submit(np.concatenate(
            [prompt, rng.integers(0, cfg.vocab_size, 6)]), 4)
        assert clus.replica_of(c1) == home
        clus.run()
        rep = clus.replicas[home]
        assert rep.slo.summary()["prefix_hit_tokens"] > 0
        # an unrelated prompt is no affinity signal (below
        # prefix_min_tokens) — it takes the least-outstanding fallback
        unrelated = rng.integers(0, cfg.vocab_size, 9)
        assert rep.prefix_match_len(unrelated) == 0
        hits_before = rep.slo.prefix_hit_tokens_total
        clus.submit(unrelated, 4)
        clus.run()
        assert len(clus.completed) == 3
        # ... and serving it produced no new hits anywhere
        assert sum(r.slo.prefix_hit_tokens_total
                   for r in clus.replicas) == hits_before
