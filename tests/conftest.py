"""Shared pytest configuration: marker registration + src-layout path.

Markers:
  fast — cheap unit tests (default CI gate runs ``-m "not slow"``).
  slow — engine/benchmark integration tests that jit full model steps.
"""
import os
import sys

# make `import repro` work without PYTHONPATH=src or an editable install
_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "fast: cheap unit tests (run in the default CI gate)")
    config.addinivalue_line(
        "markers",
        "slow: engine integration tests that jit full model step functions")
