"""Shared pytest configuration: marker registration + src-layout path +
hypothesis profiles.

Markers:
  fast — cheap unit tests (default CI gate runs ``-m "not slow"``).
  slow — engine/benchmark integration tests that jit full model steps.

Hypothesis profiles (``HYPOTHESIS_PROFILE`` env var, default "ci"):
  ci      — few examples; keeps property suites inside the fast gate.
  nightly — the slow profile the nightly CI job runs: many more random
            prompts/chunk-splits through the chunked-prefill equivalence
            suite.  Tests that pin their own ``max_examples`` in a
            ``@settings`` decorator are unaffected by the profile.
"""
import os
import sys

# make `import repro` work without PYTHONPATH=src or an editable install
_ROOT = os.path.dirname(os.path.dirname(__file__))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
# ... and `import benchmarks.*` (shared bench/test harnesses, e.g. the
# engine-parity serve in benchmarks/bench_moe_kernels.py)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=10, deadline=None)
    settings.register_profile("nightly", max_examples=100, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:          # hypothesis is optional (tests importorskip)
    pass


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "fast: cheap unit tests (run in the default CI gate)")
    config.addinivalue_line(
        "markers",
        "slow: engine integration tests that jit full model step functions")
