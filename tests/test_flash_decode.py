"""Flash-decode Pallas kernel vs the numpy oracle: shape/dtype sweeps,
position masking, and the fp8-cache path (in-kernel dequant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_pallas


def _mk(b, kv, g, hd, s, seed=0, cache_dtype=jnp.bfloat16):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, kv, g, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, kv, s, hd)), cache_dtype)
    v = jnp.asarray(rng.normal(size=(b, kv, s, hd)), cache_dtype)
    pos = jnp.asarray(rng.integers(0, s, b), jnp.int32)
    return q, k, v, pos


class TestFlashDecode:
    @pytest.mark.parametrize("b,kv,g,hd,s,bs", [
        (2, 2, 4, 64, 256, 128),
        (1, 4, 6, 128, 512, 512),
        (4, 1, 2, 32, 1024, 256),
        (3, 2, 1, 64, 384, 128),
    ])
    def test_matches_ref(self, b, kv, g, hd, s, bs):
        q, k, v, pos = _mk(b, kv, g, hd, s)
        got = np.asarray(flash_decode_pallas(q, k, v, pos, block_s=bs),
                         np.float32)
        want = ref.flash_decode_ref(np.asarray(q, np.float32),
                                    np.asarray(k, np.float32),
                                    np.asarray(v, np.float32),
                                    np.asarray(pos))
        np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)

    def test_masking_excludes_future(self):
        """Entries past pos must not influence the result."""
        q, k, v, pos = _mk(2, 1, 2, 32, 256, seed=1)
        pos = jnp.asarray([100, 37], jnp.int32)
        out1 = np.asarray(flash_decode_pallas(q, k, v, pos, block_s=64))
        # poison the masked region
        kp = np.asarray(k, np.float32)
        vp = np.asarray(v, np.float32)
        for i, p in enumerate(np.asarray(pos)):
            kp[i, :, p + 1:] = 1e4
            vp[i, :, p + 1:] = -1e4
        out2 = np.asarray(flash_decode_pallas(
            q, jnp.asarray(kp, jnp.bfloat16), jnp.asarray(vp, jnp.bfloat16),
            pos, block_s=64))
        np.testing.assert_array_equal(out1, out2)

    def test_fp8_cache_dequant_in_kernel(self):
        """fp8-stored cache: kernel output tracks the f32 oracle on the
        fp8-rounded values (the HBM read is 1 byte/elem)."""
        q, k8, v8, pos = _mk(2, 2, 2, 64, 256, seed=2,
                             cache_dtype=jnp.float8_e4m3fn)
        got = np.asarray(flash_decode_pallas(q, k8, v8, pos, block_s=128),
                         np.float32)
        want = ref.flash_decode_ref(
            np.asarray(q, np.float32),
            np.asarray(k8.astype(jnp.float32)),
            np.asarray(v8.astype(jnp.float32)), np.asarray(pos))
        np.testing.assert_allclose(got, want, rtol=4e-2, atol=4e-2)

    def test_matches_model_attention_decode(self):
        """Kernel == the jnp attention_decode scores/value math (same
        cache layout as the model: [B, KV, S, hd], grouped queries)."""
        from repro.models import layers as L
        from repro.configs import get_config
        cfg = get_config("qwen3-4b").reduced()
        dims = L.attn_dims(cfg, 1)
        b, s = 2, 64
        rng = np.random.default_rng(3)
        k = jnp.asarray(rng.normal(size=(b, dims.kv, s, dims.head_dim)),
                        jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(b, dims.kv, s, dims.head_dim)),
                        jnp.bfloat16)
        qg = jnp.asarray(rng.normal(
            size=(b, dims.kv, dims.group, dims.head_dim)), jnp.bfloat16)
        pos = jnp.asarray([10, 50], jnp.int32)
        got = np.asarray(flash_decode_pallas(qg, k, v, pos, block_s=32),
                         np.float32)
        # reference path identical to layers.attention_decode internals
        logits = jnp.einsum("bkgh,bksh->bkgs",
                            qg.astype(jnp.float32), k.astype(jnp.float32),
                            ) / np.sqrt(dims.head_dim)
        valid = jnp.arange(s)[None, :] <= pos[:, None]
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        want = np.asarray(jnp.einsum("bkgs,bksh->bkgh", p,
                                     v.astype(jnp.float32)))
        np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)
