"""Integration tests for the scaled serving engine: paged-KV
equivalence, power-of-two bucket reuse vs the seed fixed-bucket
scheduler, preemption under page pressure, and the traffic harness."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import (EngineConfig, ServingEngine, TrafficConfig,
                           generate_trace, replay_closed_loop,
                           replay_open_loop)
from repro.sharding.policy import make_dist

pytestmark = pytest.mark.slow


def _engine(name="mixtral-8x22b", **kw):
    cfg = get_config(name).reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25) if cfg.is_moe else 1
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, ep, spd)
                 if cfg.is_moe else None)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert
                     if placement else None)
    ecfg = EngineConfig(**{"max_batch": 4, "max_len": 64,
                           "rebalance_every": 0, **kw})
    return cfg, ServingEngine(cfg, dist, params, ecfg)


def _serve(cfg, eng, lengths, gen=6, seed=0):
    rng = np.random.default_rng(seed)
    for n in lengths:
        eng.submit(rng.integers(0, cfg.vocab_size, n), gen)
    eng.run()
    return {rid: tuple(r.generated) for rid, r in eng.completed.items()}


def _serve_var(cfg, eng, lengths, gens, seed=0):
    rng = np.random.default_rng(seed)
    for n, g in zip(lengths, gens):
        eng.submit(rng.integers(0, cfg.vocab_size, n), g)
    eng.run()
    return {rid: tuple(r.generated) for rid, r in eng.completed.items()}


class TestPagedEquivalence:
    def test_paged_reads_bitexact_vs_dense(self):
        """Same token stream through the paged pool and the dense
        [max_batch, max_len] cache must generate identical tokens.
        (Both engines pin prefill_mode="wave": the monolithic wave path
        is the one numerical program the two layouts share — chunked
        prefill's own equivalence is tests/test_chunked_prefill.py.)"""
        lengths = (5, 9, 3, 12, 7)
        cfg, ep = _engine(kv_layout="paged", page_size=8,
                          prefill_mode="wave")
        out_p = _serve(cfg, ep, lengths)
        cfg, ed = _engine(kv_layout="dense")
        out_d = _serve(cfg, ed, lengths)
        assert out_p == out_d
        assert ep.kvman.pages_in_use == 0      # everything released

    def test_preemption_under_page_pressure_completes(self):
        """A pool sized for ~2 resident sequences still serves 4 slots:
        the engine preempts + recomputes instead of failing."""
        cfg, eng = _engine(kv_layout="paged", page_size=8, num_pages=16)
        rng = np.random.default_rng(0)
        rids = [eng.submit(rng.integers(0, cfg.vocab_size, 20), 30)
                for _ in range(4)]
        s = eng.run()
        assert s["requests"] == 4
        assert s["preemptions"] > 0
        for rid in rids:
            assert len(eng.completed[rid].generated) == 30

    @pytest.mark.parametrize("name", ["gemma3-12b", "jamba-1.5-large-398b"])
    def test_swa_and_hybrid_archs_serve(self, name):
        """Paged attention + slot-gathered mamba state cover the SWA and
        hybrid layer stacks too."""
        cfg, eng = _engine(name)
        out = _serve(cfg, eng, (5, 9), gen=4)
        assert len(out) == 2
        assert all(len(v) == 4 for v in out.values())

    def test_mamba_wave_prefill_matches_per_request(self):
        """The SSM recurrence has no position mask, so the prefill-state
        handoff must be read at each row's true length: for a pure-SSM
        arch (no batch-global MoE routing) a packed mixed-length wave
        must generate exactly what one-request-at-a-time prefill does."""
        lengths = (3, 11, 6, 17)
        cfg, e_wave = _engine("falcon-mamba-7b", batch_prefill=True,
                              prefill_mode="wave")
        out_w = _serve(cfg, e_wave, lengths, gen=5)
        cfg, e_one = _engine("falcon-mamba-7b", batch_prefill=False,
                             prefill_mode="wave")
        out_o = _serve(cfg, e_one, lengths, gen=5)
        assert out_w == out_o


class TestBucketing:
    def test_bucketed_decode_identical_tokens(self):
        """Isolate decode bucketing: pow2 vs fixed with identical wave
        prefill and paged KV must generate bit-identical tokens (padding
        rows are masked out of MoE routing, so routing — and therefore
        the numerics — cannot depend on the bucket size), while running
        strictly less padded decode work."""
        lengths = (5, 12, 25, 9, 7, 30)
        gens = (6, 9, 4, 12, 7, 5)              # staggered drain-down
        cfg, e_p = _engine(max_batch=8, bucket_mode="pow2",
                           bucket_compile_grace=0)
        out_p = _serve_var(cfg, e_p, lengths, gens)
        cfg, e_f = _engine(max_batch=8, bucket_mode="fixed")
        out_f = _serve_var(cfg, e_f, lengths, gens)
        assert out_p == out_f
        # pow2 exercised smaller buckets and reused each compile
        buckets = e_p.slo.compile_events["decode"]
        assert any(b < 8 for b in buckets)
        assert e_p.slo.compile_count("decode") < e_p.decode_steps

    def test_fewer_compiles_than_seed_scheduler(self):
        """The rebuilt engine (pow2 buckets + chunked prefill + paged
        KV) triggers strictly fewer step-function compiles than the
        seed scheduler (fixed bucket, dense KV, one prefill call per
        request) on a trace spanning several prompt-length classes, and
        serves every request to completion.  Chunk calls have ONE
        static token length, so prompt-length diversity costs the
        chunked engine no extra signatures at all."""
        lengths = (5, 12, 25, 50, 7, 30, 11, 44)
        cfg, e_seed = _engine(bucket_mode="fixed", kv_layout="dense",
                              batch_prefill=False)
        out_seed = _serve(cfg, e_seed, lengths)
        cfg, e_new = _engine()              # pow2 + paged + chunked/mixed
        out_new = _serve(cfg, e_new, lengths)
        assert len(out_new) == len(out_seed) == len(lengths)
        assert all(len(v) == 6 for v in out_new.values())
        assert e_new.slo.total_compiles < e_seed.slo.total_compiles
        # bucket REUSE: far fewer compiles than decode steps
        assert e_new.slo.compile_count("decode") < e_new.decode_steps

    def test_exact_buckets_compile_after_grace(self):
        """A sustained low-occupancy phase earns its own (smaller)
        bucket after bucket_compile_grace steps."""
        cfg, eng = _engine(bucket_compile_grace=2)
        rng = np.random.default_rng(1)
        eng.submit(rng.integers(0, cfg.vocab_size, 6), 4)  # lone request
        eng.run()
        # only bucket 1 was ever needed; it compiled immediately
        assert eng.slo.compile_events["decode"] == [1]
        eng.submit(rng.integers(0, cfg.vocab_size, 6), 12)
        eng.run()
        # bucket 1 reused: no new decode compiles
        assert eng.slo.compile_events["decode"] == [1]


class TestTrafficHarness:
    def test_open_loop_replay_completes_and_reports(self):
        cfg, eng = _engine(max_batch=8, page_size=8)
        trace = generate_trace(TrafficConfig(
            num_requests=10, arrival_rate=200.0, seed=3,
            prompt_len_max=30, output_len_mean=6, output_len_max=8,
            vocab_size=cfg.vocab_size))
        s = replay_open_loop(eng, trace, step_time=5e-3)
        assert s["requests"] == 10
        for key in ("ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99",
                    "decode_step_p99_s", "total_compiles",
                    "queue_depth_max"):
            assert key in s
        assert s["ttft_p50"] > 0

    def test_closed_loop_keeps_concurrency(self):
        cfg, eng = _engine(max_batch=4)
        trace = generate_trace(TrafficConfig(
            num_requests=8, seed=4, prompt_len_max=20,
            output_len_mean=5, output_len_max=6,
            vocab_size=cfg.vocab_size))
        s = replay_closed_loop(eng, trace, concurrency=3)
        assert s["requests"] == 8
        assert s["queue_depth_max"] <= 3

    def test_trace_is_deterministic(self):
        a = generate_trace(TrafficConfig(num_requests=5, seed=7))
        b = generate_trace(TrafficConfig(num_requests=5, seed=7))
        assert all(np.array_equal(x.prompt, y.prompt)
                   and x.arrival == y.arrival
                   and x.max_new_tokens == y.max_new_tokens
                   for x, y in zip(a, b))
