"""Roofline-simulator sanity: the paper's qualitative structure must
hold in the model (these are the relationships Figs. 5/9/10 rest on)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.core.metrics import A100_40G, moe_layer_runtime, TPU_V5E
from repro.sim import (ParallelismConfig, WorkloadConfig,
                       simulate_decode_step, simulate_serving)
from repro.sim.roofline import LayerTrace


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-30b-a3b")
    par = ParallelismConfig(tp=1, ep=8)
    wl = WorkloadConfig(zipf_alpha=1.2, domains=4)
    return cfg, par, wl


class TestSimulator:
    def test_metro_never_slower_at_any_ratio(self, setup):
        cfg, par, wl = setup
        for ratio in (1.0, 1.25, 1.5):
            r = {a: simulate_serving(cfg, A100_40G, par, wl, algo=a,
                                     replication_ratio=ratio,
                                     decode_batch=256, n_requests=16,
                                     seed=3)
                 for a in ("eplb", "metro")}
            # allow a small routing-overhead epsilon at low ratios
            assert r["metro"]["tpot_s"] <= r["eplb"]["tpot_s"] * 1.02

    def test_eplb_tpot_grows_with_replication(self, setup):
        cfg, par, wl = setup
        tp = [simulate_serving(cfg, A100_40G, par, wl, algo="eplb",
                               replication_ratio=rr, decode_batch=256,
                               n_requests=16, seed=3)["tpot_s"]
              for rr in (1.0, 1.25, 1.5)]
        assert tp[0] < tp[1] < tp[2], "paper Fig. 5b structure"

    def test_metro_reduces_activated_at_1_5(self, setup):
        cfg, par, wl = setup
        r = {a: simulate_serving(cfg, A100_40G, par, wl, algo=a,
                                 replication_ratio=1.5,
                                 decode_batch=256, n_requests=16,
                                 seed=3)
             for a in ("eplb", "metro")}
        assert r["metro"]["max_activated"] < r["eplb"]["max_activated"]

    def test_memory_bound_layer_model(self):
        """More activated experts -> more time, at equal token counts."""
        tok = np.full(8, 64.0)
        t1 = moe_layer_runtime(np.full(8, 8), tok, d_model=2048,
                               d_ff=768, bytes_per_param=2, hw=TPU_V5E)
        t2 = moe_layer_runtime(np.full(8, 16), tok, d_model=2048,
                               d_ff=768, bytes_per_param=2, hw=TPU_V5E)
        assert t2 > t1 * 1.5

    def test_trace_loads_match_sampling(self):
        rng = np.random.default_rng(0)
        tr = LayerTrace(rng, 64, 1.2, domains=4)
        ids = tr.sample(rng, 4000, 4)
        emp = np.bincount(ids.reshape(-1), minlength=64) / (4000 * 4)
        model = tr.loads() / tr.loads().sum()
        # top-decile hot sets should agree
        hot_emp = set(np.argsort(emp)[-6:])
        hot_mod = set(np.argsort(model)[-6:])
        assert len(hot_emp & hot_mod) >= 4
