"""Serving-engine integration: continuous batching, prefill+decode
co-deployment, METRO routing in the decode phase, EPLB rebalancing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import EngineConfig, ServingEngine
from repro.sharding.policy import make_dist

pytestmark = pytest.mark.slow


def _engine(name="mixtral-8x22b", **kw):
    cfg = get_config(name).reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25) if cfg.is_moe else 1
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, ep, spd)
                 if cfg.is_moe else None)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert
                     if placement else None)
    ecfg = EngineConfig(max_batch=4, max_len=64, **kw)
    return cfg, ServingEngine(cfg, dist, params, ecfg)


class TestEngine:
    def test_serves_batch_to_completion(self):
        cfg, eng = _engine(rebalance_every=0)
        rng = np.random.default_rng(0)
        rids = [eng.submit(rng.integers(0, cfg.vocab_size, n), 8)
                for n in (5, 9, 3, 12, 7)]
        summary = eng.run()
        assert summary["requests"] == 5
        done = eng.finished_requests()
        assert set(done) == set(rids)
        for t in done.values():
            assert t.n_generated == 8
        assert summary["tpot_mean"] > 0
        assert summary["total_token_throughput"] > 0

    def test_metro_vs_eplb_same_tokens(self):
        """Routing algo must not change generated tokens (replicas are
        identical); it only changes WHERE compute happens."""
        outs = {}
        for algo in ("metro", "eplb"):
            cfg, eng = _engine(decode_algo=algo, rebalance_every=0)
            rng = np.random.default_rng(1)
            prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in range(3)]
            for p in prompts:
                eng.submit(p, 6)
            eng.run()
            outs[algo] = {rid: tuple(r.generated)
                          for rid, r in eng.completed.items()}
        assert outs["metro"] == outs["eplb"]

    def test_rebalance_preserves_outputs(self):
        """EPLB reshuffling moves replicas but must not change math."""
        cfg, eng = _engine(rebalance_every=0)
        rng = np.random.default_rng(2)
        p = rng.integers(0, cfg.vocab_size, 8)
        eng.submit(p, 4)
        eng.run()
        base = list(eng.completed.values())[0].generated

        cfg2, eng2 = _engine(rebalance_every=2)
        eng2.submit(p, 4)
        eng2.run()
        got = list(eng2.completed.values())[0].generated
        assert base == got

    def test_continuous_batching_admits_late_arrivals(self):
        cfg, eng = _engine(rebalance_every=0)
        rng = np.random.default_rng(3)
        for n in (5, 6, 7, 8, 9, 10):   # 6 requests > 4 slots
            eng.submit(rng.integers(0, cfg.vocab_size, n), 5)
        summary = eng.run()
        assert summary["requests"] == 6

    def test_dense_arch_serves(self):
        cfg, eng = _engine("qwen3-4b", rebalance_every=0)
        rng = np.random.default_rng(4)
        eng.submit(rng.integers(0, cfg.vocab_size, 6), 5)
        summary = eng.run()
        assert summary["requests"] == 1


