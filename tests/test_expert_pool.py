"""Expert-weight pool lockdown: allocator discipline, activation-aware
prefetch, the paged megakernel, and engine-level bit-identity.

The subsystem's one non-negotiable claim mirrors the prefix cache's:
paging expert weights through a capacity-limited HBM frame pool
changes *nothing* observable about a serve — generated tokens are
bitwise identical to the all-resident run.  The pool is bookkeeping
plus virtual-time cost (a fetch always completes before use), so
residency may never leak into the math.

Fast half: page/frame allocator mechanics (LRU eviction order, pin
blocks eviction, release-keeps-resident, rebalance invalidation,
capacity floor), prefetch plan/depth/gate split, a hypothesis fuzz of
the acquire/release/plan/flush/invalidate lifecycle with
``check_consistent`` after every op, the one-step-ahead prefetch
oracle (coverage == 1.0), and the paged double-buffered megakernel's
numerics (permuted frame maps, interior dead tiles, all-dead grids).

Slow half: engine-level parity (capacity-limited pool vs no pool,
moe_impl="fused_paged" vs "ragged") through the real serving engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.expert_pool import (ExpertPagePool, build_expert_pool,
                                       expert_page_bytes, moe_layer_count)

PB = 64          # page_bytes used by the unit-test pools


def _pool(n_layers=2, n_slots=4, num_frames=6, depth=8):
    return ExpertPagePool(n_layers=n_layers, n_slots=n_slots,
                          page_bytes=PB, num_frames=num_frames,
                          prefetch_depth=depth)


# ======================================================================
# fast: allocator mechanics
# ======================================================================


@pytest.mark.fast
class TestPoolAllocator:
    def test_page_geometry_from_config(self):
        cfg = get_config("mixtral-8x22b").reduced()
        n_up = 2 if cfg.gated_mlp else 1
        want = (cfg.d_model * n_up * cfg.expert_hidden
                + cfg.expert_hidden * cfg.d_model) * 2
        assert expert_page_bytes(cfg) == want
        kinds = cfg.layer_kinds()
        n_moe = sum(1 for _, f in kinds if f == "moe")
        assert moe_layer_count(cfg) == \
            (cfg.num_layers // len(kinds)) * n_moe

    def test_acquire_release_keeps_page_resident(self):
        pool = _pool()
        pid = pool.page_id(0, 1)
        res = pool.acquire([pid])
        assert res == {"hits": 0, "misses": 1, "planned_hits": 0,
                       "miss_bytes": PB}
        pool.release([pid])
        # unlike KV release, the page stays cached until eviction
        assert pool.resident(pid)
        assert pool.acquire([pid])["hits"] == 1
        pool.release([pid])
        pool.check_consistent()

    def test_lru_evicts_least_recently_touched(self):
        pool = _pool(n_layers=2, n_slots=4, num_frames=4)
        pids = [pool.page_id(0, s) for s in range(4)]
        pool.acquire(pids)
        pool.release(pids)
        # retouch everything except pids[1] -> it is the LRU victim
        pool.acquire([pids[0], pids[2], pids[3]])
        pool.release([pids[0], pids[2], pids[3]])
        # the pool is full: fetching a layer-1 page must evict pids[1]
        other = pool.page_id(1, 0)
        pool.acquire([other])
        pool.release([other])
        assert pool.evictions == 1
        assert not pool.resident(pids[1])
        assert all(pool.resident(p) for p in (pids[0], pids[2],
                                              pids[3], other))
        pool.check_consistent()

    def test_eviction_skips_pinned_frames(self):
        pool = _pool(n_layers=2, n_slots=3, num_frames=3)
        pids = [pool.page_id(0, s) for s in range(3)]
        pool.acquire(pids)                  # all three frames pinned
        other = pool.page_id(1, 0)
        with pytest.raises(RuntimeError):
            pool.acquire([other])           # nothing evictable
        pool.release([pids[0]])             # one unpinned
        pool.acquire([other])               # evicts exactly pids[0]
        assert not pool.resident(pids[0])
        assert pool.resident(pids[1]) and pool.resident(pids[2])
        assert pool.evictions == 1
        pool.release([other, pids[1], pids[2]])
        pool.check_consistent()

    def test_capacity_floor_one_layer_slot_set(self):
        with pytest.raises(AssertionError):
            ExpertPagePool(n_layers=2, n_slots=4, page_bytes=PB,
                           num_frames=3)
        # build_expert_pool floors a too-small budget at n_slots frames
        cfg = get_config("mixtral-8x22b").reduced()

        class ECfg:
            hbm_budget_bytes = 1            # absurdly small
            pool_h2d_bw = 1.6e10
            prefetch_depth = 8

        pool = build_expert_pool(cfg, ECfg, n_slots=12)
        assert pool.num_frames == 12

    def test_all_resident_when_budget_zero(self):
        cfg = get_config("mixtral-8x22b").reduced()

        class ECfg:
            hbm_budget_bytes = 0
            pool_h2d_bw = 1.6e10
            prefetch_depth = 8

        pool = build_expert_pool(cfg, ECfg, n_slots=12)
        assert pool.num_frames == pool.total_pages
        # every page fetches once (compulsory) and never evicts
        all_pids = list(range(pool.total_pages))
        pool.acquire(all_pids)
        pool.release(all_pids)
        assert pool.acquire(all_pids)["misses"] == 0
        pool.release(all_pids)
        assert pool.evictions == 0
        pool.check_consistent()

    def test_invalidate_slots_drops_all_layers(self):
        pool = _pool(n_layers=2, n_slots=4, num_frames=8)
        pids = [pool.page_id(li, s) for li in range(2) for s in range(4)]
        pool.acquire(pids)
        pool.release(pids)
        dropped = pool.invalidate_slots([1, 3])
        assert dropped == 4                 # 2 slots x 2 layers
        for li in range(2):
            assert not pool.resident(pool.page_id(li, 1))
            assert not pool.resident(pool.page_id(li, 3))
            assert pool.resident(pool.page_id(li, 0))
        assert pool.invalidations == 4
        pool.check_consistent()

    def test_invalidate_pinned_page_asserts(self):
        pool = _pool(n_layers=1, n_slots=2, num_frames=2)
        pid = pool.page_id(0, 0)
        pool.acquire([pid])
        with pytest.raises(AssertionError):
            pool.invalidate_slots([0])


@pytest.mark.fast
class TestPrefetchPlan:
    def test_depth_splits_prefetch_and_gate(self):
        pool = _pool(n_layers=2, n_slots=4, num_frames=8, depth=2)
        pids = [pool.page_id(0, s) for s in range(4)]
        issued = pool.plan_prefetch(pids)
        assert issued == 2 * PB             # depth caps overlapped DMA
        assert pool.prefetch_bytes == 2 * PB
        gate = pool.flush_pending()
        assert gate == 2 * PB               # the deferred remainder
        assert pool.gate_bytes == 2 * PB
        assert all(pool.resident(p) for p in pids)
        # a second flush is a no-op
        assert pool.flush_pending() == 0
        pool.check_consistent()

    def test_planned_hit_counts_even_when_not_resident(self):
        pool = _pool(n_layers=1, n_slots=4, num_frames=4, depth=1)
        pids = [pool.page_id(0, s) for s in range(3)]
        pool.plan_prefetch(pids)            # only pids[0] fetched
        res = pool.acquire(pids)
        # all three were planned (coverage), two still missed
        assert res["planned_hits"] == 3
        assert res["misses"] == 2
        pool.release(pids)
        assert pool.prefetch_coverage == 1.0
        assert pool.hit_rate == pytest.approx(1 / 3)
        pool.check_consistent()

    def test_depth_zero_disables_planning(self):
        pool = _pool(depth=0)
        pids = [pool.page_id(0, s) for s in range(4)]
        assert pool.plan_prefetch(pids) == 0
        assert pool.flush_pending() == 0
        assert pool.prefetch_bytes == 0 and pool.gate_bytes == 0
        res = pool.acquire(pids)            # everything demand-misses
        assert res["misses"] == 4 and res["planned_hits"] == 0
        pool.release(pids)
        pool.check_consistent()

    def test_oracle_router_one_step_ahead(self):
        """When step t's plan names exactly step t+1's accesses and
        depth is ample, coverage is 1.0 and nothing misses or gates
        after the warmup step."""
        rng = np.random.default_rng(0)
        pool = _pool(n_layers=2, n_slots=6, num_frames=12, depth=64)
        trace = [sorted(rng.choice(12, size=4, replace=False))
                 for _ in range(20)]
        # warmup: step 0 has no plan yet
        pool.acquire(trace[0])
        pool.release(trace[0])
        pool.plan_prefetch(trace[1])
        warm_misses = pool.misses
        for t in range(1, len(trace)):
            assert pool.flush_pending() == 0, "ample depth never gates"
            res = pool.acquire(trace[t])
            assert res["misses"] == 0, f"step {t} missed under oracle"
            assert res["planned_hits"] == len(trace[t])
            pool.release(trace[t])
            if t + 1 < len(trace):
                pool.plan_prefetch(trace[t + 1])
            pool.check_consistent()
        assert pool.misses == warm_misses
        assert pool.prefetch_coverage == pytest.approx(
            (pool.accesses - len(trace[0])) / pool.accesses)
        assert pool.gate_bytes == 0

    def test_bytes_by_kind_ledger(self):
        pool = _pool(n_layers=1, n_slots=4, num_frames=4, depth=1)
        pool.acquire([0], kind="chunk")
        pool.release([0])
        pool.plan_prefetch([1, 2], kind="decode")
        pool.flush_pending(kind="decode")
        pool.acquire([3], kind="decode")
        pool.release([3])
        c = pool.counters()
        assert c["bytes_by_kind"]["chunk"]["miss"] == PB
        assert c["bytes_by_kind"]["decode"]["prefetch"] == PB
        assert c["bytes_by_kind"]["decode"]["gate"] == PB
        assert c["bytes_by_kind"]["decode"]["miss"] == PB
        assert c["h2d_bytes"] == 4 * PB


# ======================================================================
# fast: hypothesis fuzz of the page lifecycle
# ======================================================================


@pytest.mark.fast
class TestPoolLifecycleFuzz:
    def test_invariants_hold_under_random_ops(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(st.data())
        @settings(deadline=None)
        def prop(data):
            n_layers = data.draw(st.integers(1, 3))
            n_slots = data.draw(st.integers(2, 5))
            total = n_layers * n_slots
            frames = data.draw(st.integers(n_slots, total))
            pool = ExpertPagePool(n_layers=n_layers, n_slots=n_slots,
                                  page_bytes=8, num_frames=frames,
                                  prefetch_depth=data.draw(
                                      st.integers(0, 4)))
            pinned = []                     # acquired, not yet released
            n_ops = data.draw(st.integers(5, 40))
            for _ in range(n_ops):
                ops = ["step", "plan", "flush"]
                if not pinned:
                    ops.append("invalidate")
                op = data.draw(st.sampled_from(ops))
                if op == "step":
                    # one layer's access set, like the executor: at most
                    # n_slots pages pinned at once, released same step
                    li = data.draw(st.integers(0, n_layers - 1))
                    k = data.draw(st.integers(0, n_slots))
                    slots = data.draw(st.permutations(range(n_slots)))
                    pids = [pool.page_id(li, s) for s in slots[:k]]
                    res = pool.acquire(pids)
                    assert res["hits"] + res["misses"] == len(pids)
                    pool.check_consistent()
                    pool.release(pids)
                elif op == "plan":
                    k = data.draw(st.integers(0, total))
                    pids = data.draw(st.permutations(range(total)))[:k]
                    pool.plan_prefetch(list(pids))
                elif op == "flush":
                    pool.flush_pending()
                elif op == "invalidate":
                    k = data.draw(st.integers(0, n_slots))
                    slots = data.draw(
                        st.permutations(range(n_slots)))[:k]
                    pool.invalidate_slots(list(slots))
                pool.check_consistent()
            # ledger closes: every fetched byte is accounted to a kind
            c = pool.counters()
            by_kind = sum(sum(v.values())
                          for v in c["bytes_by_kind"].values())
            assert by_kind == c["h2d_bytes"]
            assert (pool.refcount == 0).all()

        prop()


# ======================================================================
# fast: paged double-buffered megakernel numerics
# ======================================================================


def _ffn_oracle(x, wu, wd, tile_group, tile, fe, gated):
    out = np.zeros((len(tile_group) * tile, wd.shape[2]), np.float32)
    for i, g in enumerate(tile_group):
        if g < 0:
            continue
        xt = np.asarray(x[i * tile:(i + 1) * tile])
        h = (xt @ np.asarray(wu[g])).astype(np.float32)
        if gated:
            act = np.asarray(jax.nn.silu(h[:, :fe])) * h[:, fe:]
        else:
            act = np.asarray(jax.nn.gelu(h))
        out[i * tile:(i + 1) * tile] = \
            act.astype(np.float32) @ np.asarray(wd[g])
    return out


@pytest.mark.fast
class TestPagedKernel:
    D, FE, S, TILE = 16, 24, 5, 4

    def _weights(self, gated, seed=3):
        rng = np.random.default_rng(seed)
        n_up = 2 if gated else 1
        wu = jnp.asarray(rng.normal(size=(self.S, self.D, n_up * self.FE))
                         * 0.1, jnp.float32)
        wd = jnp.asarray(rng.normal(size=(self.S, self.FE, self.D))
                         * 0.1, jnp.float32)
        return rng, wu, wd

    def test_matches_oracle_arbitrary_dead_patterns(self):
        from repro.kernels.moe_ffn import fused_expert_ffn_paged_pallas
        for gated in (True, False):
            rng, wu, wd = self._weights(gated)
            fm = jnp.arange(self.S, dtype=jnp.int32)
            for tg_l in ([0, 3, 3, -1, 1], [2], [0, 1, 2, 3, 4],
                         [1, -1, 1], [-1, 0]):
                tg = jnp.asarray(tg_l, jnp.int32)
                x = jnp.asarray(rng.normal(
                    size=(len(tg_l) * self.TILE, self.D)), jnp.float32)
                got = fused_expert_ffn_paged_pallas(
                    x, wu, wd, fm, tg, gated=gated)
                want = _ffn_oracle(x, wu, wd, tg_l, self.TILE, self.FE,
                                   gated)
                np.testing.assert_allclose(np.asarray(got), want,
                                           rtol=1e-5, atol=1e-5)
                dead = np.repeat(np.asarray(tg_l) < 0, self.TILE)
                assert np.all(np.asarray(got)[dead] == 0)

    def test_permuted_frame_map(self):
        """Physical frame placement is the pool's business: permuting
        the frames and inverting the map must not change the output."""
        from repro.kernels.moe_ffn import fused_expert_ffn_paged_pallas
        rng, wu, wd = self._weights(True)
        tg = jnp.asarray([0, 4, 2, -1], jnp.int32)
        x = jnp.asarray(rng.normal(size=(4 * self.TILE, self.D)),
                        jnp.float32)
        ident = fused_expert_ffn_paged_pallas(
            x, wu, wd, jnp.arange(self.S, dtype=jnp.int32), tg,
            gated=True)
        perm = rng.permutation(self.S)
        fm = jnp.asarray(np.argsort(perm), jnp.int32)  # slot -> frame
        got = fused_expert_ffn_paged_pallas(x, wu[perm], wd[perm], fm,
                                            tg, gated=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ident))

    def test_all_dead_grid_is_exact_zeros(self):
        from repro.kernels.moe_ffn import fused_expert_ffn_paged_pallas
        rng, wu, wd = self._weights(True)
        tg = jnp.asarray([-1, -1, -1], jnp.int32)
        x = jnp.asarray(rng.normal(size=(3 * self.TILE, self.D)),
                        jnp.float32)
        got = fused_expert_ffn_paged_pallas(
            x, wu, wd, jnp.arange(self.S, dtype=jnp.int32), tg,
            gated=True)
        assert not np.asarray(got).any()

    def test_trailing_dead_matches_automatic_pipeline(self):
        """On the layouts build_pair_buffer guarantees (trailing dead
        tiles), the paged kernel is bitwise-equal to the automatic-
        pipeline fused kernel."""
        from repro.kernels.moe_ffn import (fused_expert_ffn_paged_pallas,
                                           fused_expert_ffn_pallas)
        for gated in (True, False):
            rng, wu, wd = self._weights(gated)
            for tg_l in ([0, 2, 2, -1, -1], [3, -1], [1, 4, 0]):
                tg = jnp.asarray(tg_l, jnp.int32)
                x = jnp.asarray(rng.normal(
                    size=(len(tg_l) * self.TILE, self.D)), jnp.float32)
                a = fused_expert_ffn_pallas(x, wu, wd, tg, gated=gated)
                b = fused_expert_ffn_paged_pallas(
                    x, wu, wd, jnp.arange(self.S, dtype=jnp.int32), tg,
                    gated=gated)
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))


# ======================================================================
# slow: engine-level bit-identity through the real serving stack
# ======================================================================


@pytest.mark.slow
class TestEnginePoolParity:
    def _serve(self, **kw):
        from benchmarks.bench_moe_kernels import serve_tokens
        return serve_tokens(**kw)

    def test_capacity_limited_pool_tokens_identical(self):
        """A pool holding one layer's slot set (full thrash, constant
        eviction) must serve bit-identical tokens to no pool at all."""
        from repro.serving import EngineConfig, ServingEngine
        from repro.core import build_placement, slots_for_ratio
        from repro.models import init_lm
        from repro.sharding.policy import make_dist
        cfg = get_config("mixtral-8x22b").reduced()
        ep = 4
        spd = slots_for_ratio(cfg.num_experts, ep, 1.25)
        dist = make_dist(None, ep_size=ep, slots_per_device=spd)
        placement = build_placement(cfg.num_experts, ep, spd)
        params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                         replica_expert=placement.replica_expert)

        def serve(**pool_kw):
            eng = ServingEngine(cfg, dist, params, EngineConfig(
                max_batch=4, max_len=64, moe_impl="ragged",
                decode_algo="metro", rebalance_every=0, **pool_kw))
            rng = np.random.default_rng(7)
            for n in (5, 9, 3):
                eng.submit(rng.integers(0, cfg.vocab_size, n), 4)
            eng.run()
            return ({r: tuple(q.generated)
                     for r, q in eng.completed.items()}, eng)

        base, _ = serve()
        tight = expert_page_bytes(cfg) * dist.num_slots
        toks, eng = serve(expert_pool=True, hbm_budget_bytes=tight,
                          prefetch_depth=4)
        assert toks == base
        pool = eng.expert_pool
        assert pool.num_frames < pool.total_pages  # capacity-limited
        assert pool.evictions > 0                  # it really thrashed
        pool.check_consistent()
        s = eng.slo.summary()
        assert s["expert_pool_hits"] == pool.hits
        assert s["expert_pool_misses"] == pool.misses
        assert 0.0 < s["expert_pool_hit_rate"] < 1.0

    def test_fused_paged_datapath_token_parity(self):
        """moe_impl="fused_paged" (the double-buffered DMA megakernel)
        serves the same tokens as the ragged reference datapath."""
        a = self._serve(impl="ragged", prompt_lens=(5, 9))
        b = self._serve(impl="fused_paged", prompt_lens=(5, 9))
        assert a == b
