"""Numerical equivalence of the distributed MoE datapath vs local mode.

Runs in a subprocess with 8 forced host devices (the device count must
be set before jax initializes, so it cannot run in the main pytest
process): the shard_map EP datapath (all-gather dispatch + psum_scatter
combine, tokens AND features modes, with ETP weight sharding) must
produce the same numbers as the mesh-less virtual-EP path.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.core import build_placement, slots_for_ratio
    from repro.models import moe as MOE
    from repro.models import lm as LM
    from repro.sharding.policy import make_dist
    from repro.launch.steps import tree_named, step_pspecs, StepConfig
    from repro.sharding.policy import param_pspecs

    cfg = get_config("mixtral-8x22b").reduced()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25)
    dist_m = make_dist(mesh, slots_per_device=spd)
    dist_l = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = build_placement(cfg.num_experts, ep, spd)
    p = MOE.init_moe(cfg, jax.random.PRNGKey(0), dist_l,
                     placement.replica_expert)
    tables = MOE.routing_tables(placement)
    rng = np.random.default_rng(0)
    x3 = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)), jnp.float32)

    # ---- local (virtual EP) reference ----
    ref_tok, _ = MOE.moe_ffn(cfg, dist_l, p, tables, x3, algo="eplb",
                             mode="tokens")
    ref_feat, _ = MOE.moe_ffn(cfg, dist_l, p, tables, x3[:, 0],
                              algo="metro", mode="features")

    # ---- distributed: shard params per the policy ----
    pspec = param_pspecs(p, dist_m)
    p_sharded = jax.device_put(p, tree_named(dist_m, pspec))
    got_tok, _ = jax.jit(lambda pp, xx: MOE.moe_ffn(
        cfg, dist_m, pp, tables, xx, algo="eplb", mode="tokens"))(
        p_sharded, x3)
    got_feat, _ = jax.jit(lambda pp, xx: MOE.moe_ffn(
        cfg, dist_m, pp, tables, xx, algo="metro", mode="features"))(
        p_sharded, x3[:, 0])

    np.testing.assert_allclose(np.asarray(ref_tok, np.float32),
                               np.asarray(got_tok, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(ref_feat, np.float32),
                               np.asarray(got_feat, np.float32),
                               rtol=2e-2, atol=2e-2)
    print("DISPATCH_EQUIVALENCE_OK")
""")


def test_shard_map_matches_local():
    env = dict(os.environ, PYTHONPATH=str(SRC))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "DISPATCH_EQUIVALENCE_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-3000:])
