"""Engine-level equivalences for chunked prefill + mixed steps.

  * A fused mixed step (prefill chunk + decode in ONE call) is
    token-for-token identical — and identical in per-call expert_hist —
    to the pure-phase chunk-then-decode sequence it replaces, under both
    METRO and EPLB decode routing.
  * Preemption BETWEEN prefill chunks releases the victim's pages, is
    counted once, and readmission recomputes to the exact logical KV
    state of a run that was never preempted (no double-written pages).
  * The chunked engine still serves every arch family to completion and
    matches the dense/wave engines' completion guarantees.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import EngineConfig, ServingEngine
from repro.sharding.policy import make_dist

pytestmark = pytest.mark.slow


def _engine(name="mixtral-8x22b", **kw):
    cfg = get_config(name).reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25) if cfg.is_moe else 1
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, ep, spd)
                 if cfg.is_moe else None)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert
                     if placement else None)
    ecfg = EngineConfig(**{"max_batch": 4, "max_len": 64,
                           "rebalance_every": 0, **kw})
    return cfg, ServingEngine(cfg, dist, params, ecfg)


def _serve_staggered(cfg, eng, lengths, gen=6, seed=0, every=2):
    """Submit prompts a few engine iterations apart so prefill chunks
    overlap live decode — the co-deployed regime mixed steps target."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in lengths]
    it = iter(prompts)
    eng.submit(next(it), gen)
    k = 0
    while eng.has_work:
        eng.step()
        k += 1
        if k % every == 0:
            nxt = next(it, None)
            if nxt is not None:
                eng.submit(nxt, gen)
    while True:            # drain any unsubmitted (short traces)
        nxt = next(it, None)
        if nxt is None:
            break
        eng.submit(nxt, gen)
        eng.run()
    return {rid: tuple(r.generated) for rid, r in eng.completed.items()}


class TestMixedStepEquivalence:
    @pytest.mark.parametrize("algo", ["metro", "eplb"])
    def test_mixed_equals_pure_phase(self, algo):
        """Fusion must be invisible: same tokens, same per-call
        expert_hist sequence, same number of prefill chunks."""
        lengths = (5, 30, 9, 22, 7, 15)
        kw = dict(prefill_chunk=8, decode_algo=algo)
        cfg, e_mix = _engine(mixed_steps=True, **kw)
        out_mix = _serve_staggered(cfg, e_mix, lengths)
        cfg, e_pure = _engine(mixed_steps=False, **kw)
        out_pure = _serve_staggered(cfg, e_pure, lengths)
        assert out_mix == out_pure
        assert len(out_mix) == len(lengths)
        hm, hp = e_mix.expert_hist_log, e_pure.expert_hist_log
        assert len(hm) == len(hp)
        for a, b in zip(hm, hp):
            np.testing.assert_array_equal(a, b)
        # fusion actually happened (and stalls vanished with it)
        s = e_mix.slo.summary()
        assert s["mixed_steps"] > 0
        assert s["decode_stall_events"] == 0
        assert e_pure.slo.summary()["decode_stall_events"] > 0

    def test_budget_caps_prefill_tokens_per_iteration(self):
        """mixed_prefill_budget bounds per-iteration prefill work but
        not the final tokens (numerics are schedule-invariant)."""
        lengths = (40, 25, 10)
        cfg, e_all = _engine(prefill_chunk=8, mixed_prefill_budget=0)
        out_all = _serve_staggered(cfg, e_all, lengths)
        cfg, e_cap = _engine(prefill_chunk=8, mixed_prefill_budget=8)
        out_cap = _serve_staggered(cfg, e_cap, lengths)
        assert out_all == out_cap
        assert len(out_cap) == len(lengths)

    def test_mixed_serves_hybrid_and_swa(self):
        for name in ("gemma3-12b", "jamba-1.5-large-398b"):
            cfg, eng = _engine(name, prefill_chunk=8)
            out = _serve_staggered(cfg, eng, (5, 20, 9), gen=4)
            assert len(out) == 3
            assert all(len(v) == 4 for v in out.values())


class TestPreemptionBetweenChunks:
    def test_preempt_mid_prefill_releases_pages_and_counts_once(self):
        cfg, eng = _engine(prefill_chunk=8, page_size=4, num_pages=16)
        rng = np.random.default_rng(0)
        r0 = eng.submit(rng.integers(0, cfg.vocab_size, 6), 20)
        eng.step()                          # r0 prefilled + first token
        r1 = eng.submit(rng.integers(0, cfg.vocab_size, 30), 5)
        eng.step()                          # r1's first chunk only
        req1 = eng.active[r1]
        assert 0 < req1.pos < req1.n_ctx    # genuinely mid-prefill
        used_before = eng.kvman.pages_in_use
        assert eng._preempt_one(protect_rid=r0)
        assert eng.slo.preemptions == 1     # counted exactly once
        assert r1 not in eng.active
        assert eng.queue[0].rid == r1
        assert req1.pos == 0                # recompute from scratch
        assert eng.kvman.pages_in_use < used_before
        eng.kvman.check_consistent()        # no double-mapped pages
        # readmission recomputes and completes both requests
        eng.run()
        assert len(eng.completed) == 2
        assert eng.kvman.pages_in_use == 0
        eng.kvman.check_consistent()

    def test_readmission_recomputes_exact_state(self):
        """The observable for exact recompute: the preempted request's
        logical KV pages (gathered through its page table) — and its
        generated tokens — are bitwise identical to a run that was
        never preempted."""
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, get_config("mixtral-8x22b")
                              .reduced().vocab_size, 20)

        def logical_kv(eng, slot):
            pt = eng.kvman.page_table[slot]
            out = []
            for li, pool in eng.cache.items():
                if "conv" in pool:
                    out.append(np.asarray(pool["h"][:, slot]))
                    out.append(np.asarray(pool["conv"][:, slot]))
                    continue
                for key in ("k", "v"):
                    arr = np.asarray(pool[key])     # [nb, P, ps, kv, hd]
                    for lp in pt[pt >= 0]:          # logical page order
                        out.append(arr[:, lp])
            return out

        def run_three_chunks(preempt):
            cfg, eng = _engine(prefill_chunk=8, page_size=4)
            rid = eng.submit(prompt, 4)
            eng.step()                      # chunk 1 (pos=8)
            if preempt:
                assert eng._preempt_one(protect_rid=-1)
                assert eng.slo.preemptions == 1
                eng.kvman.check_consistent()
                eng.step()                  # readmit + chunk 1 again
                eng.step()                  # chunk 2
            else:
                eng.step()                  # chunk 2 (pos=16)
            eng.step()                      # final chunk + first decode
            req = (eng.active.get(rid) or eng.completed.get(rid))
            assert req.pos == req.n_ctx + 1
            return eng, req

        e_clean, r_clean = run_three_chunks(preempt=False)
        e_evict, r_evict = run_three_chunks(preempt=True)
        assert r_clean.generated == r_evict.generated
        kv_c = logical_kv(e_clean, r_clean.slot)
        kv_e = logical_kv(e_evict, r_evict.slot)
        assert len(kv_c) == len(kv_e)
        for a, b in zip(kv_c, kv_e):
            np.testing.assert_array_equal(a, b)
        e_evict.kvman.check_consistent()

    def test_natural_pressure_preempts_mid_prefill_and_completes(self):
        """End-to-end: a tight pool repeatedly evicts the youngest
        request — including while it is only partway through chunked
        prefill.  Every request still finishes with its full token
        count, the allocator invariants hold throughout, and any
        request whose evictions all happened BETWEEN prefill chunks (or
        that was never evicted) generates exactly the tokens of an
        uncontended run.  (Mid-decode victims recompute correctly but
        not bitwise — replaying prompt+generated collapses the re-fed
        boundary token; seed semantics, see ServingEngine._preempt_one.
        The bitwise mid-prefill claim is pinned deterministically by
        test_readmission_recomputes_exact_state above.)"""
        lengths, gens = (10, 12, 8, 40), (24, 20, 16, 6)
        rng = np.random.default_rng(2)
        cfg = get_config("mixtral-8x22b").reduced()
        prompts = [rng.integers(0, cfg.vocab_size, n) for n in lengths]

        def serve(**kw):
            cfg2, eng = _engine(prefill_chunk=8, page_size=4, **kw)
            for p, g in zip(prompts, gens):
                eng.submit(p, g)
            eng.run()
            return eng

        tight = serve(num_pages=24)         # pmax=16: under 4 full seqs
        assert len(tight.completed) == len(lengths)
        assert tight.slo.preemptions > 0
        # evictions genuinely landed between prefill chunks
        assert sum(r.preempted_in_prefill
                   for r in tight.completed.values()) > 0
        tight.kvman.check_consistent()
        assert tight.kvman.pages_in_use == 0
        roomy = serve()                     # full residency, no pressure
        assert roomy.slo.preemptions == 0
        exact = 0
        for rid, r in roomy.completed.items():
            rt = tight.completed[rid]
            assert len(rt.generated) == len(r.generated)
            if rt.preempted == rt.preempted_in_prefill:
                assert rt.generated == r.generated
                exact += 1
        assert exact >= 1
