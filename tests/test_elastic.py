"""Elastic scaling: a checkpoint written under one mesh must restore
onto a different mesh (different device count + axis split) with
identical values — the re-shard happens in `checkpoint.restore` via
device_put with the new NamedShardings.

Subprocess: device counts must be fixed before jax init.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

SCRIPT = textwrap.dedent("""
    import os, sys, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.core import build_placement, slots_for_ratio
    from repro.models import lm as LM
    from repro.sharding.policy import make_dist, param_pspecs
    from repro.launch.steps import tree_named
    from repro.training import checkpoint as CKPT

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    ckpt = tempfile.mkdtemp()

    # --- "big" mesh: 2x4 ---
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    spd_a = slots_for_ratio(cfg.num_experts, 4, 1.0)
    dist_a = make_dist(mesh_a, slots_per_device=spd_a)
    pl = build_placement(cfg.num_experts, 4, spd_a)
    params = LM.init_lm(cfg, jax.random.PRNGKey(0), dist_a,
                        replica_expert=pl.replica_expert)
    shard_a = tree_named(dist_a, param_pspecs(params, dist_a))
    params = jax.device_put(params, shard_a)
    CKPT.save(ckpt, 7, params)

    # --- "shrunk" mesh: 4x2 (elastic downscale / axis re-split) ---
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    dist_b = make_dist(mesh_b, slots_per_device=spd_a * 2)
    shard_b = tree_named(dist_b, param_pspecs(params, dist_b))
    restored, meta = CKPT.restore(ckpt, params, shardings=shard_b)
    assert meta["step"] == 7

    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert getattr(b, "sharding", None) is not None
    # spot-check: restored leaf actually lives on the new mesh
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 4, "model": 2}
    print("ELASTIC_RESTORE_OK")
""")


def test_elastic_restore_across_meshes():
    env = dict(os.environ, PYTHONPATH=str(SRC))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "ELASTIC_RESTORE_OK" in out.stdout, (
        out.stdout[-2000:] + "\n" + out.stderr[-3000:])
