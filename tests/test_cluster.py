"""Cluster-layer lockdown: the refactor seam and the multi-replica
router.

  * **Seam determinism** — a single-replica ``ClusterEngine`` is token-
    and expert_hist-identical to a bare ``ServingEngine`` on the same
    trace, for both METRO and EPLB decode routing: the cluster layer
    adds dispatch and placement sharing, never numerics.
  * **Rebalance safety** — reshuffling the physical expert weights to a
    new EPLB placement *while a chunked prefill is mid-prompt* leaves
    generated tokens and per-call expert_hist bitwise unchanged
    (replica choice moves compute, not math), and the scheduler's
    ``rebalance_defer_prefill`` window holds a due local rebalance
    until prefills drain.
  * **Router** — round-robin and least-outstanding-work dispatch are
    deterministic, spread load, and serve every request; the shared
    placement is installed on every replica at the common window.
  * **Traffic spawning** — per-replica derived RNG streams are
    reproducible and uncorrelated.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.serving import (ClusterConfig, ClusterEngine, EngineConfig,
                           ServingEngine, TrafficConfig, generate_trace,
                           spawn_traffic_configs)
from repro.sharding.policy import make_dist

pytestmark = pytest.mark.slow


_SETUP_CACHE: dict = {}


def _setup(name="mixtral-8x22b"):
    if name not in _SETUP_CACHE:
        cfg = get_config(name).reduced()
        ep = 4
        spd = slots_for_ratio(cfg.num_experts, ep, 1.25) \
            if cfg.is_moe else 1
        dist = make_dist(None, ep_size=ep, slots_per_device=spd)
        placement = (build_placement(cfg.num_experts, ep, spd)
                     if cfg.is_moe else None)
        params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                         replica_expert=placement.replica_expert
                         if placement else None)
        _SETUP_CACHE[name] = (cfg, dist, params)
    return _SETUP_CACHE[name]


def _ecfg(**kw):
    return EngineConfig(**{"max_batch": 4, "max_len": 64,
                           "rebalance_every": 0, "prefill_chunk": 8,
                           **kw})


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n) for n in lengths]


def _tokens(completed):
    return {rid: tuple(r.generated) for rid, r in completed.items()}


class TestSingleReplicaSeam:
    """The refactor seam: cluster(1) == bare engine, bit for bit."""

    @pytest.mark.parametrize("algo", ["metro", "eplb"])
    def test_single_replica_cluster_equals_bare_engine(self, algo):
        cfg, dist, params = _setup()
        lengths = (5, 30, 9, 22, 7)
        prompts = _prompts(cfg, lengths)

        bare = ServingEngine(cfg, dist,
                             jax.tree.map(lambda a: a, params),
                             _ecfg(decode_algo=algo))
        for p in prompts:
            bare.submit(p, 6)
        bare.run()

        clus = ClusterEngine(cfg, dist, params,
                             _ecfg(decode_algo=algo),
                             ClusterConfig(num_replicas=1),
                             step_cost=None)
        for p in prompts:
            clus.submit(p, 6)
        clus.run()

        assert _tokens(clus.completed) == _tokens(bare.completed)
        assert len(clus.completed) == len(lengths)
        hb = bare.expert_hist_log
        hc = clus.replicas[0].expert_hist_log
        assert len(hb) == len(hc) > 0
        for a, b in zip(hb, hc):
            np.testing.assert_array_equal(a, b)

    def test_virtual_clock_does_not_change_tokens(self):
        """The virtual-time cost model only relabels seconds: tokens
        and hist are identical to the wall-clock run."""
        cfg, dist, params = _setup()
        prompts = _prompts(cfg, (5, 20, 9))

        def serve(step_cost):
            clus = ClusterEngine(cfg, dist, params, _ecfg(),
                                 ClusterConfig(num_replicas=1),
                                 step_cost=step_cost)
            for p in prompts:
                clus.submit(p, 5)
            s = clus.run()
            return _tokens(clus.completed), s

        out_wall, _ = serve(None)
        out_virt, s = serve(
            lambda kind, n, st: 1e-3 + 1e-4 * st["max_activated"])
        assert out_wall == out_virt
        # virtual summaries are deterministic functions of the schedule
        out_virt2, s2 = serve(
            lambda kind, n, st: 1e-3 + 1e-4 * st["max_activated"])
        assert s["tpot_p99"] == s2["tpot_p99"]
        assert s["ttft_p99"] == s2["ttft_p99"]


class TestRebalanceSafety:
    def test_rebalance_mid_prefill_is_bitwise_invisible(self):
        """Force a shared-placement reshuffle while a long prompt is
        between chunks: tokens AND per-call expert_hist must match a
        run that never rebalanced — replica→expert weight reshuffling
        moves compute, not math."""
        cfg, dist, params = _setup()
        prompts = _prompts(cfg, (40, 6), seed=3)

        def serve(kick):
            eng = ServingEngine(cfg, dist,
                                jax.tree.map(lambda a: a, params),
                                _ecfg())
            eng.submit(prompts[0], 5)
            eng.submit(prompts[1], 5)
            eng.step()                      # first chunks in flight
            r0 = eng.active[0]
            assert r0.prefilling            # genuinely mid-prompt
            if kick:
                # skew the load signal so the placement really changes
                eng.state.expert_loads = np.arange(
                    1.0, cfg.num_experts + 1.0)
                before = eng.placement.replica_expert.copy()
                eng.rebalance()
                assert not np.array_equal(
                    before, eng.placement.replica_expert), \
                    "rebalance was a no-op; test is vacuous"
            eng.run()
            return eng

        clean = serve(kick=False)
        moved = serve(kick=True)
        assert _tokens(clean.completed) == _tokens(moved.completed)
        hc, hm = clean.expert_hist_log, moved.expert_hist_log
        assert len(hc) == len(hm)
        for a, b in zip(hc, hm):
            np.testing.assert_array_equal(a, b)

    def test_rebalance_window_defers_until_prefill_drains(self):
        """With rebalance_defer_prefill (default), a window that lands
        while a chunked prefill is in flight stays pending and fires on
        the first decode step with no prefill in flight (prefills here
        drain well inside the one-window deferral bound)."""
        cfg, dist, params = _setup()
        eng = ServingEngine(cfg, dist,
                            jax.tree.map(lambda a: a, params),
                            _ecfg(rebalance_every=4))
        fired = []
        orig = eng.exec.rebalance
        eng.exec.rebalance = lambda *a, **k: (
            fired.append(eng.state.prefills_in_flight()), orig(*a, **k))
        prompts = _prompts(cfg, (40, 6), seed=4)
        eng.submit(prompts[0], 4)
        eng.submit(prompts[1], 4)
        eng.run()
        assert fired, "rebalance never fired"
        assert all(n == 0 for n in fired), \
            f"rebalance fired with prefills in flight: {fired}"

    def test_rebalance_deferral_is_bounded(self):
        """Sustained prefill pressure cannot starve the window: after
        one extra window of deferral the rebalance fires even with a
        prefill still in flight."""
        cfg, dist, params = _setup()
        eng = ServingEngine(cfg, dist,
                            jax.tree.map(lambda a: a, params),
                            _ecfg(rebalance_every=1))
        fired = []
        orig = eng.exec.rebalance
        eng.exec.rebalance = lambda *a, **k: (
            fired.append(eng.state.prefills_in_flight()), orig(*a, **k))
        prompts = _prompts(cfg, (6, 40), seed=5)
        eng.submit(prompts[0], 8)       # live decoder
        eng.step()
        eng.submit(prompts[1], 4)       # long prompt: several chunks
        eng.run()
        assert any(n > 0 for n in fired), \
            "bounded deferral never forced a mid-prefill rebalance"

    def test_rebalance_window_immediate_without_guard(self):
        """rebalance_defer_prefill=False restores the unguarded window:
        with a long prompt mid-prefill next to live decoders, some
        window fires while a prefill is in flight."""
        cfg, dist, params = _setup()
        eng = ServingEngine(cfg, dist,
                            jax.tree.map(lambda a: a, params),
                            _ecfg(rebalance_every=1,
                                  rebalance_defer_prefill=False))
        fired = []
        orig = eng.exec.rebalance
        eng.exec.rebalance = lambda *a, **k: (
            fired.append(eng.state.prefills_in_flight()), orig(*a, **k))
        prompts = _prompts(cfg, (6, 40), seed=5)
        eng.submit(prompts[0], 8)       # live decoder
        eng.step()
        eng.submit(prompts[1], 4)       # long prompt: several chunks
        eng.run()
        assert any(n > 0 for n in fired)


class TestClusterRouter:
    @pytest.mark.parametrize("dispatch", ["rr", "low"])
    def test_two_replicas_serve_all_and_spread(self, dispatch):
        cfg, dist, params = _setup()
        clus = ClusterEngine(cfg, dist, params, _ecfg(),
                             ClusterConfig(num_replicas=2,
                                           dispatch=dispatch))
        trace = generate_trace(TrafficConfig(
            num_requests=8, arrival_rate=500.0, seed=6,
            prompt_len_max=30, output_len_mean=5, output_len_max=6,
            vocab_size=cfg.vocab_size))
        s = clus.replay_open_loop(trace)
        assert s["requests"] == 8
        assert len(clus.completed) == 8
        homes = {clus.replica_of(crid) for crid in clus.completed}
        assert homes == {0, 1}, f"dispatch used only replicas {homes}"
        assert all(len(r.generated) == trace[crid].max_new_tokens
                   for crid, r in clus.completed.items())
        # rollup structure
        assert len(s["replicas"]) == 2
        assert sum(s["requests_per_replica"]) == 8
        assert s["tpot_p99"] >= s["tpot_p50"] >= 0

    def test_round_robin_alternates(self):
        cfg, dist, params = _setup()
        clus = ClusterEngine(cfg, dist, params, _ecfg(),
                             ClusterConfig(num_replicas=2,
                                           dispatch="rr"),
                             step_cost=None)
        for p in _prompts(cfg, (4, 4, 4, 4)):
            clus.submit(p, 2)
        assert [clus.replica_of(i) for i in range(4)] == [0, 1, 0, 1]

    def test_low_dispatch_prefers_idle_replica(self):
        cfg, dist, params = _setup()
        clus = ClusterEngine(cfg, dist, params, _ecfg(),
                             ClusterConfig(num_replicas=2,
                                           dispatch="low"),
                             step_cost=None)
        big, small = _prompts(cfg, (40, 5), seed=7)
        clus.submit(big, 20)            # replica 0 gets the heavy one
        assert clus.replica_of(0) == 0
        clus.submit(small, 2)           # must go to the empty replica
        assert clus.replica_of(1) == 1

    def test_shared_placement_installed_on_all_replicas(self):
        cfg, dist, params = _setup()
        clus = ClusterEngine(cfg, dist, params, _ecfg(),
                             ClusterConfig(num_replicas=2,
                                           rebalance_every=4))
        trace = generate_trace(TrafficConfig(
            num_requests=6, arrival_rate=500.0, seed=8,
            prompt_len_max=20, output_len_mean=6, output_len_max=8,
            vocab_size=cfg.vocab_size))
        clus.replay_open_loop(trace)
        assert clus.rebalances > 0
        a, b = (r.placement.replica_expert for r in clus.replicas)
        np.testing.assert_array_equal(a, b)

    def test_replica_compile_sharing(self):
        """N identical replicas share one jit cache: each shape
        signature compiles once across the fleet, not once per
        replica."""
        cfg, dist, params = _setup()
        clus = ClusterEngine(cfg, dist, params, _ecfg(),
                             ClusterConfig(num_replicas=2,
                                           dispatch="rr"))
        for p in _prompts(cfg, (6, 6, 6, 6), seed=9):
            clus.submit(p, 4)
        clus.run()
        assert len(clus.completed) == 4
        total = sum(r.slo.total_compiles for r in clus.replicas)
        distinct = sum(len(v) for v in clus.replicas[0]._fns.values())
        assert total == distinct, \
            "a shape signature was compiled more than once fleet-wide"


class TestTrafficSpawning:
    def test_spawned_streams_reproducible_and_uncorrelated(self):
        base = TrafficConfig(num_requests=16, seed=42)
        cfgs_a = spawn_traffic_configs(base, 3)
        cfgs_b = spawn_traffic_configs(base, 3)
        # reproducible: same parent seed -> same children
        assert [c.seed for c in cfgs_a] == [c.seed for c in cfgs_b]
        # uncorrelated: distinct children, distinct traces
        assert len({c.seed for c in cfgs_a}) == 3
        traces = [generate_trace(c) for c in cfgs_a]
        for i in range(3):
            for j in range(i + 1, 3):
                assert not all(
                    np.array_equal(x.prompt, y.prompt)
                    for x, y in zip(traces[i], traces[j])), \
                    f"replica traces {i} and {j} are identical"
        # and unequal to the parent's own trace
        parent = generate_trace(base)
        assert not all(np.array_equal(x.prompt, y.prompt)
                       for x, y in zip(parent, traces[0]))

    def test_spawn_differs_from_naive_increment(self):
        base = TrafficConfig(num_requests=4, seed=0)
        spawned = spawn_traffic_configs(base, 2)
        assert spawned[0].seed != base.seed
        assert spawned[1].seed != base.seed + 1
        assert dataclasses.asdict(spawned[0]) != dataclasses.asdict(base) \
            or True  # seeds checked above; configs otherwise identical
        assert spawned[0].num_requests == base.num_requests
