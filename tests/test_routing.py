"""Unit tests for METRO / EPLB routing and EPLB placement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_placement, slots_for_ratio, route_metro, route_eplb,
    route_single, metro_token_slots, topk_histogram, rank_within_expert,
    routing_stats, solve_min_exp_routing,
)


def _toy_placement():
    # 4 experts, 4 devices, 2 slots/device -> 8 slots (2x replication)
    return build_placement(4, 4, 2, loads=np.array([4.0, 3.0, 2.0, 1.0]))


class TestPlacement:
    def test_all_experts_hosted(self):
        p = _toy_placement()
        assert set(p.replica_expert.tolist()) == {0, 1, 2, 3}

    def test_replica_counts_follow_load(self):
        p = _toy_placement()
        # heavier experts get at least as many replicas
        c = p.expert_num_replicas
        assert c[0] >= c[3]
        assert c.sum() == 8

    def test_slots_for_ratio_divisibility(self):
        # qwen2-moe case from the paper's assigned archs: 60 experts, 16 EP
        s = slots_for_ratio(60, 16, 1.0)
        assert s * 16 >= 60
        p = build_placement(60, 16, s)
        assert p.num_slots == 64

    def test_no_colocated_replicas_when_avoidable(self):
        p = build_placement(8, 8, 2, loads=np.ones(8))
        for d in range(8):
            slots = p.replica_expert[d * 2:(d + 1) * 2]
            assert slots[0] != slots[1]


class TestHistogramAndRank:
    def test_histogram(self):
        e = jnp.array([[0, 1], [1, 2], [-1, 1]])
        t = topk_histogram(e, 4)
        assert t.tolist() == [1, 3, 1, 0]

    def test_rank_within_expert(self):
        e = jnp.array([2, 0, 2, 2, 0, -1])
        r = rank_within_expert(e)
        # expert 2 appears at flat pos 0,2,3 -> ranks 0,1,2; expert 0 at 1,4
        assert r[0] == 0 and r[2] == 1 and r[3] == 2
        assert r[1] == 0 and r[4] == 1


class TestMetro:
    def test_lemma1_single_replica_per_expert(self):
        """Lemma 1: METRO routes all tokens of an expert to ONE replica."""
        p = _toy_placement()
        ids = jnp.array(np.random.default_rng(0).integers(0, 4, (32, 2)))
        t = topk_histogram(ids, 4)
        es = route_metro(t, jnp.asarray(p.expert_slots),
                         num_devices=4, slots_per_device=2)
        slots = metro_token_slots(ids, es)
        for e in range(4):
            used = np.unique(np.asarray(slots)[np.asarray(ids) == e])
            assert len(used) <= 1

    def test_respects_placement(self):
        p = _toy_placement()
        ids = jnp.array(np.random.default_rng(1).integers(0, 4, (16, 2)))
        t = topk_histogram(ids, 4)
        es = np.asarray(route_metro(t, jnp.asarray(p.expert_slots),
                                    num_devices=4, slots_per_device=2))
        for e in range(4):
            if es[e] >= 0:
                assert p.replica_expert[es[e]] == e

    def test_inactive_experts_not_activated(self):
        p = _toy_placement()
        t = jnp.array([5, 0, 3, 0])
        es = np.asarray(route_metro(t, jnp.asarray(p.expert_slots),
                                    num_devices=4, slots_per_device=2))
        assert es[1] == -1 and es[3] == -1
        assert es[0] >= 0 and es[2] >= 0

    def test_matches_optimal_on_toy(self):
        """Fig. 4's toy regime: METRO should reach the ideal lambda."""
        # 4 experts each on 2 of 4 devices, all active -> optimal lambda = 1
        p = build_placement(4, 4, 2, loads=np.ones(4))
        t = jnp.array([4, 4, 4, 4])
        es = route_metro(t, jnp.asarray(p.expert_slots),
                         num_devices=4, slots_per_device=2)
        ids = jnp.repeat(jnp.arange(4), 4).reshape(-1, 1)
        slots = metro_token_slots(ids, es)
        stats = routing_stats(slots, p)
        lam_opt, _ = solve_min_exp_routing(np.asarray(t), p.placement_matrix())
        assert stats.max_activated == lam_opt == 1

    def test_metro_beats_eplb_on_paper_example(self):
        """Paper Fig. 4: token balancing doubles activated experts."""
        # 8 experts, 8 devices, 2 slots each (2x replication), 16 tokens,
        # 2 tokens per expert (the figure's setup).
        p = build_placement(8, 8, 2, loads=np.ones(8))
        ids = jnp.repeat(jnp.arange(8), 2).reshape(-1, 1)
        t = topk_histogram(ids, 8)
        es = route_metro(t, jnp.asarray(p.expert_slots),
                         num_devices=8, slots_per_device=2)
        m_slots = metro_token_slots(ids, es)
        e_slots = route_eplb(ids, jnp.asarray(p.expert_slots),
                             jnp.asarray(p.expert_num_replicas))
        m = routing_stats(m_slots, p)
        e = routing_stats(e_slots, p)
        assert m.max_activated == 1
        assert e.max_activated == 2  # EPLB splits across both replicas
        assert m.max_activated < e.max_activated


class TestEplb:
    def test_round_robin_even_split(self):
        p = _toy_placement()
        e0_reps = int(p.expert_num_replicas[0])
        ids = jnp.zeros((8, 1), jnp.int32)  # 8 tokens all to expert 0
        slots = np.asarray(route_eplb(ids, jnp.asarray(p.expert_slots),
                                      jnp.asarray(p.expert_num_replicas)))
        counts = {s: int((slots == s).sum()) for s in np.unique(slots)}
        assert len(counts) == e0_reps
        assert max(counts.values()) - min(counts.values()) <= 1

    def test_invalid_pairs_pass_through(self):
        p = _toy_placement()
        ids = jnp.array([[0, -1]])
        slots = np.asarray(route_eplb(ids, jnp.asarray(p.expert_slots),
                                      jnp.asarray(p.expert_num_replicas)))
        assert slots[0, 1] == -1
        assert slots[0, 0] >= 0

    def test_single_route(self):
        p = _toy_placement()
        ids = jnp.array([[2, -1]])
        s = np.asarray(route_single(ids, jnp.asarray(p.expert_slots)))
        assert s[0, 0] == p.expert_slots[2, 0]
        assert s[0, 1] == -1


class TestOptimal:
    def test_feasibility_bounds(self):
        rng = np.random.default_rng(2)
        p = build_placement(16, 4, 5, loads=rng.random(16))
        t = rng.integers(0, 10, 16)
        lam, assign = solve_min_exp_routing(t, p.placement_matrix())
        active = (t > 0)
        # every active expert assigned, respecting placement
        A = p.placement_matrix()
        for e in np.nonzero(active)[0]:
            assert assign[e] >= 0 and A[e, assign[e]] == 1
        per_dev = np.bincount(assign[assign >= 0], minlength=4)
        assert per_dev.max() == lam
        assert lam >= int(np.ceil(active.sum() / 4))

    def test_zero_tokens(self):
        p = _toy_placement()
        lam, assign = solve_min_exp_routing(np.zeros(4), p.placement_matrix())
        assert lam == 0 and (assign == -1).all()
