"""Paged KV-cache unit tests: the free-list allocator, the paged
attention read/write path, and the page-table-indexed Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import (flash_decode_paged,
                                        flash_decode_pallas,
                                        flash_prefill_paged)
from repro.kernels.ref import flash_prefill_paged_ref
from repro.serving.kv import PagedKVManager, pages_for

pytestmark = pytest.mark.fast


class TestAllocator:
    def test_pages_for(self):
        assert pages_for(0, 16) == 0
        assert pages_for(1, 16) == 1
        assert pages_for(16, 16) == 1
        assert pages_for(17, 16) == 2

    def test_incremental_growth_and_release(self):
        m = PagedKVManager(num_pages=8, page_size=4, max_pages_per_seq=4,
                           max_seqs=3)
        assert m.ensure(0, 5)                  # 2 pages
        assert m.owned(0) == 2 and m.num_free == 6
        assert m.ensure(0, 5)                  # idempotent
        assert m.owned(0) == 2
        assert m.ensure(0, 9)                  # grow to 3
        assert m.owned(0) == 3
        assert (m.page_table[0, :3] >= 0).all()
        assert m.page_table[0, 3] == -1
        freed = m.release(0)
        assert freed == 3 and m.num_free == 8
        assert (m.page_table[0] == -1).all()

    def test_exhaustion_allocates_nothing(self):
        m = PagedKVManager(num_pages=4, page_size=4, max_pages_per_seq=4,
                           max_seqs=4)
        assert m.ensure(0, 12)                 # 3 pages
        assert not m.ensure(1, 8)              # needs 2, only 1 free
        assert m.owned(1) == 0                 # all-or-nothing
        assert m.num_free == 1
        assert m.ensure(1, 4)                  # 1 page still fits

    def test_pages_unique_across_slots(self):
        m = PagedKVManager(num_pages=16, page_size=4, max_pages_per_seq=4,
                           max_seqs=4)
        for s in range(4):
            assert m.ensure(s, 16)
        used = m.page_table[m.page_table >= 0]
        assert len(np.unique(used)) == 16

    def test_one_seq_must_fit(self):
        with pytest.raises(AssertionError):
            PagedKVManager(num_pages=2, page_size=4, max_pages_per_seq=4,
                           max_seqs=2)


class TestPagedKernel:
    @pytest.mark.parametrize("b,kv,g,hd,ps,pmax,seed", [
        (2, 2, 2, 16, 8, 4, 0),
        (3, 1, 4, 32, 16, 2, 1),
        (4, 2, 1, 16, 8, 8, 2),
    ])
    def test_matches_dense_kernel(self, b, kv, g, hd, ps, pmax, seed):
        """Paged reads == dense reads on the same token stream, with the
        pool shared/shuffled across sequences."""
        rng = np.random.default_rng(seed)
        s = pmax * ps
        num_pages = b * pmax + 2
        q = jnp.asarray(rng.normal(size=(b, kv, g, hd)), jnp.float32)
        k_dense = jnp.asarray(rng.normal(size=(b, kv, s, hd)), jnp.float32)
        v_dense = jnp.asarray(rng.normal(size=(b, kv, s, hd)), jnp.float32)
        pos = jnp.asarray(rng.integers(0, s, size=b), jnp.int32)

        pt = np.full((b, pmax), -1, np.int32)
        free = list(rng.permutation(num_pages))
        k_pool = np.asarray(rng.normal(size=(num_pages, ps, kv, hd)),
                            np.float32)   # junk in unmapped pages
        v_pool = np.asarray(rng.normal(size=(num_pages, ps, kv, hd)),
                            np.float32)
        for i in range(b):
            for p in range(int(pos[i]) // ps + 1):
                pg = free.pop()
                pt[i, p] = pg
                k_pool[pg] = np.asarray(
                    k_dense[i, :, p * ps:(p + 1) * ps]).transpose(1, 0, 2)
                v_pool[pg] = np.asarray(
                    v_dense[i, :, p * ps:(p + 1) * ps]).transpose(1, 0, 2)

        got = flash_decode_paged(q, jnp.asarray(k_pool),
                                 jnp.asarray(v_pool), pos, jnp.asarray(pt))
        want = flash_decode_pallas(q, k_dense, v_dense, pos, block_s=ps)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("b,kv,g,c,hd,ps,pmax,window,seed", [
        (2, 2, 2, 4, 16, 8, 4, 0, 0),
        (3, 1, 4, 8, 32, 16, 2, 0, 1),
        (2, 2, 1, 6, 16, 8, 8, 12, 2),    # sliding window
        (1, 2, 2, 1, 16, 8, 4, 0, 3),     # C=1: decode as a chunk
    ])
    def test_prefill_kernel_matches_oracle(self, b, kv, g, c, hd, ps,
                                           pmax, window, seed):
        """The chunk-offset query window kernel (multi-token queries at
        positions start+i over the paged pool) matches the numpy oracle,
        with holes masked and optional SWA masking."""
        rng = np.random.default_rng(seed)
        num_pages = b * pmax + 2
        q = jnp.asarray(rng.normal(size=(b, kv, c, g, hd)), jnp.float32)
        k_pool = jnp.asarray(rng.normal(size=(num_pages, ps, kv, hd)),
                             jnp.float32)
        v_pool = jnp.asarray(rng.normal(size=(num_pages, ps, kv, hd)),
                             jnp.float32)
        # each row: a chunk starting somewhere inside its sequence, with
        # enough pages mapped to cover start+c (later tables keep holes)
        start = rng.integers(0, pmax * ps - c, size=b).astype(np.int32)
        pt = np.full((b, pmax), -1, np.int32)
        free = list(rng.permutation(num_pages))
        for i in range(b):
            for p in range((int(start[i]) + c - 1) // ps + 1):
                pt[i, p] = free.pop()
        got = flash_prefill_paged(q, k_pool, v_pool,
                                  jnp.asarray(start), jnp.asarray(pt),
                                  window=window)
        want = flash_prefill_paged_ref(np.asarray(q), np.asarray(k_pool),
                                       np.asarray(v_pool), start, pt,
                                       window=window)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-5, atol=1e-5)

    def test_prefill_kernel_c1_equals_decode_kernel(self):
        """A one-token chunk is exactly a decode step: the two kernels
        must agree on the same pool/page-table state."""
        rng = np.random.default_rng(4)
        b, kv, g, hd, ps, pmax = 2, 2, 2, 16, 8, 4
        num_pages = b * pmax
        q = jnp.asarray(rng.normal(size=(b, kv, g, hd)), jnp.float32)
        k_pool = jnp.asarray(rng.normal(size=(num_pages, ps, kv, hd)),
                             jnp.float32)
        v_pool = jnp.asarray(rng.normal(size=(num_pages, ps, kv, hd)),
                             jnp.float32)
        pos = jnp.asarray(rng.integers(0, pmax * ps, size=b), jnp.int32)
        pt = np.full((b, pmax), -1, np.int32)
        free = list(rng.permutation(num_pages))
        for i in range(b):
            for p in range(int(pos[i]) // ps + 1):
                pt[i, p] = free.pop()
        pt = jnp.asarray(pt)
        dec = flash_decode_paged(q, k_pool, v_pool, pos, pt)
        chk = flash_prefill_paged(q[:, :, None], k_pool, v_pool, pos, pt)
        np.testing.assert_allclose(np.asarray(chk[:, :, 0]),
                                   np.asarray(dec), rtol=1e-6, atol=1e-6)

    def test_unmapped_pages_are_masked(self):
        """Holes in the page table must not leak pool contents even when
        pos claims those positions are live."""
        b, kv, g, hd, ps, pmax = 1, 1, 1, 16, 8, 4
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(b, kv, g, hd)), jnp.float32)
        k_pool = jnp.asarray(rng.normal(size=(6, ps, kv, hd)), jnp.float32)
        v_pool = jnp.asarray(rng.normal(size=(6, ps, kv, hd)), jnp.float32)
        pos = jnp.asarray([pmax * ps - 1], jnp.int32)   # "everything live"
        pt_full = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
        pt_holes = jnp.asarray([[0, -1, 2, -1]], jnp.int32)
        out_full = flash_decode_paged(q, k_pool, v_pool, pos, pt_full)
        out_holes = flash_decode_paged(q, k_pool, v_pool, pos, pt_holes)
        # reference for the holes case: dense cache with the two mapped
        # pages only, positions of unmapped pages masked via -inf == by
        # building the dense stream and masking positions
        k_d = jnp.stack([k_pool[0], k_pool[1], k_pool[2], k_pool[3]]) \
            .reshape(1, pmax * ps, kv, hd).transpose(0, 2, 1, 3)
        v_d = jnp.stack([v_pool[0], v_pool[1], v_pool[2], v_pool[3]]) \
            .reshape(1, pmax * ps, kv, hd).transpose(0, 2, 1, 3)
        assert not np.allclose(np.asarray(out_full), np.asarray(out_holes))
        # manual softmax over only the mapped positions
        qf = np.asarray(q)[0, 0]                       # [G, hd]
        kf = np.asarray(k_d)[0, 0]                     # [S, hd]
        vf = np.asarray(v_d)[0, 0]
        mask = np.zeros(pmax * ps, bool)
        mask[0:ps] = True
        mask[2 * ps:3 * ps] = True
        logits = (qf @ kf.T) / np.sqrt(hd)
        logits[:, ~mask] = -1e30
        p = np.exp(logits - logits.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        np.testing.assert_allclose(np.asarray(out_holes)[0, 0], p @ vf,
                                   rtol=1e-5, atol=1e-5)
