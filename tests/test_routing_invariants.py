"""Deterministic invariants of the token-routing algorithms (no
hypothesis dependency — these lock down the paper's core claims even on
minimal installs).

  * Lemma 1: METRO activates exactly ONE replica per hot expert — every
    (token, k) pair of an expert lands on the same physical slot.
  * Dominance: METRO's per-device activated-expert max is <= EPLB
    round-robin's on the same placement (METRO optimizes exactly this
    objective; round-robin activates every replica of a hot expert).
  * EPLB balance: round-robin spreads an expert's tokens across its
    replicas within +-1 token.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (build_placement, route_eplb, route_metro,
                        metro_token_slots, topk_histogram)
from repro.core.metrics import activated_per_device

pytestmark = pytest.mark.fast


def _case(seed, n=16, g=4, spd=6, tokens=64, k=2, skew=1.5):
    rng = np.random.default_rng(seed)
    loads = rng.random(n) ** skew + 0.05
    placement = build_placement(n, g, spd, loads=loads)
    probs = loads / loads.sum()
    ids = np.stack([
        rng.choice(n, size=tokens, p=probs, replace=True)
        for _ in range(k)], axis=1).astype(np.int32)
    return placement, jnp.asarray(ids)


@pytest.mark.parametrize("seed", range(6))
class TestMetroLemma1:
    def test_single_replica_per_hot_expert(self, seed):
        p, ids = _case(seed)
        hist = topk_histogram(ids, p.num_experts)
        expert_slot = route_metro(
            hist, jnp.asarray(p.expert_slots),
            num_devices=p.num_devices, slots_per_device=p.slots_per_device)
        slots = np.asarray(metro_token_slots(ids, expert_slot))
        hist = np.asarray(hist)
        es = np.asarray(expert_slot)
        for e in range(p.num_experts):
            used = np.unique(slots[np.asarray(ids) == e])
            if hist[e] > 0:
                # all of expert e's pairs share its one activated replica
                assert len(used) == 1 and used[0] == es[e]
                assert es[e] in p.expert_slots[e]
            else:
                assert es[e] == -1 and len(used) == 0

    def test_metro_dominates_eplb_activation(self, seed):
        """Paper Fig. 4/8: max activated replicas per device under METRO
        is never worse than under token-balanced round-robin."""
        p, ids = _case(seed)
        hist = topk_histogram(ids, p.num_experts)
        es = route_metro(
            hist, jnp.asarray(p.expert_slots),
            num_devices=p.num_devices, slots_per_device=p.slots_per_device)
        metro_slots = metro_token_slots(ids, es)
        eplb_slots = route_eplb(ids, jnp.asarray(p.expert_slots),
                                jnp.asarray(p.expert_num_replicas))
        act_m = np.asarray(activated_per_device(
            metro_slots, p.num_devices, p.slots_per_device))
        act_e = np.asarray(activated_per_device(
            eplb_slots, p.num_devices, p.slots_per_device))
        assert act_m.max() <= act_e.max()


@pytest.mark.parametrize("seed", range(6))
def test_eplb_round_robin_within_one(seed):
    p, ids = _case(seed)
    slots = np.asarray(route_eplb(ids, jnp.asarray(p.expert_slots),
                                  jnp.asarray(p.expert_num_replicas)))
    ids_np = np.asarray(ids)
    for e in range(p.num_experts):
        mine = slots[ids_np == e]
        if len(mine) == 0:
            continue
        replicas = p.expert_slots[e][p.expert_slots[e] >= 0]
        counts = np.array([(mine == s).sum() for s in replicas])
        assert counts.sum() == len(mine)          # no foreign slots
        assert counts.max() - counts.min() <= 1   # +-1 balance
