"""Validate the trip-count-aware HLO cost walker against known programs.

Runs jax in a subprocess-free way on the default 1-device CPU (no forced
device count needed: these programs are unsharded)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compile(f, *avals):
    return jax.jit(f).lower(*avals).compile().as_text()


class TestHloCost:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((256, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 64), jnp.float32)
        txt = _compile(lambda x, y: x @ y, a, b)
        c = analyze_hlo(txt, assume_bf16_compute=False)
        assert c.flops == pytest.approx(2 * 256 * 128 * 64, rel=0.01)
        want_bytes = 4 * (256 * 128 + 128 * 64 + 256 * 64)
        assert c.dot_bytes == pytest.approx(want_bytes, rel=0.01)
        # bf16-compute correction halves float byte counts
        c2 = analyze_hlo(txt)
        assert c2.dot_bytes == pytest.approx(want_bytes / 2, rel=0.01)

    def test_scan_multiplies_trip_count(self):
        def f(x):
            def body(c, _):
                return c @ c, None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        txt = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
        c = analyze_hlo(txt)
        assert c.while_loops >= 1
        assert c.flops == pytest.approx(7 * 2 * 128 ** 3, rel=0.01)

    def test_nested_scan(self):
        def f(x):
            def inner(c, _):
                return c @ c, None

            def outer(c, _):
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None

            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        txt = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
        c = analyze_hlo(txt)
        assert c.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.01)

    def test_batched_dot(self):
        a = jax.ShapeDtypeStruct((8, 32, 16), jnp.bfloat16)
        b = jax.ShapeDtypeStruct((8, 16, 24), jnp.bfloat16)
        txt = _compile(
            lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
        c = analyze_hlo(txt)
        assert c.flops == pytest.approx(2 * 8 * 32 * 16 * 24, rel=0.01)

    def test_grad_counts_both_passes(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def loss(w):
            return jnp.sum((w @ w) ** 2)

        txt = _compile(jax.grad(loss), a)
        c = analyze_hlo(txt)
        # fwd 1 dot + bwd >= 2 dots
        assert c.flops >= 3 * 2 * 64 ** 3 * 0.9
