"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_placement, route_metro
from repro.kernels import ref
from repro.kernels.metro_route import metro_route_pallas
from repro.kernels.moe_ffn import grouped_ffn_pallas


class TestMetroRouteKernel:
    @pytest.mark.parametrize("n,g,spd,seed", [
        (8, 4, 2, 0), (16, 4, 4, 1), (60, 16, 4, 2), (128, 16, 8, 3),
        (256, 16, 16, 4),
    ])
    def test_matches_ref(self, n, g, spd, seed):
        rng = np.random.default_rng(seed)
        p = build_placement(n, g, spd, loads=rng.random(n) + 0.1)
        t = rng.integers(0, 50, n).astype(np.int32)
        t[rng.random(n) < 0.3] = 0  # cold experts
        got = np.asarray(metro_route_pallas(
            jnp.asarray(t), jnp.asarray(p.expert_slots),
            num_devices=g, slots_per_device=spd))
        want = ref.metro_route_ref(t, p.expert_slots,
                                   num_devices=g, slots_per_device=spd)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("seed", range(4))
    def test_matches_jax_scan_router(self, seed):
        """Kernel == core.routing.route_metro (the scan used in-model)."""
        rng = np.random.default_rng(seed)
        n, g, spd = 24, 8, 4
        p = build_placement(n, g, spd, loads=rng.random(n) + 0.1)
        t = jnp.asarray(rng.integers(0, 20, n), jnp.int32)
        got = metro_route_pallas(t, jnp.asarray(p.expert_slots),
                                 num_devices=g, slots_per_device=spd)
        want = route_metro(t, jnp.asarray(p.expert_slots),
                           num_devices=g, slots_per_device=spd)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_all_zero_tokens(self):
        p = build_placement(8, 4, 2)
        t = jnp.zeros(8, jnp.int32)
        got = np.asarray(metro_route_pallas(
            t, jnp.asarray(p.expert_slots), num_devices=4,
            slots_per_device=2))
        assert (got == -1).all()


class TestGroupedFfnKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("c,d,f,s,tile", [
        (64, 128, 256, 4, 8),
        (128, 256, 128, 8, 16),
        (256, 512, 512, 4, 128),
        (32, 1024, 512, 2, 8),
    ])
    def test_matches_ref(self, c, d, f, s, tile, dtype):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(c, d)), dtype)
        w = jnp.asarray(rng.normal(size=(s, d, f)) * 0.05, dtype)
        tg = jnp.asarray(
            np.sort(rng.integers(0, s, c // tile)), jnp.int32)
        got = np.asarray(grouped_ffn_pallas(x, w, tg), np.float32)
        want = ref.grouped_matmul_ref(
            np.asarray(x, np.float32), np.asarray(w, np.float32),
            np.asarray(tg))
        rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(got, want, rtol=rtol, atol=1e-2)

    def test_matches_moe_layer_grouped_matmul(self):
        """Kernel impl == the ragged_dot fast path used by the layer."""
        from repro.models.moe import grouped_matmul
        rng = np.random.default_rng(1)
        c, d, f, s, tile = 64, 128, 128, 4, 8
        x = jnp.asarray(rng.normal(size=(c, d)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(s, d, f)) * 0.05, jnp.float32)
        # tile-aligned group sizes summing to <= c
        gp = jnp.asarray([16, 0, 24, 8], jnp.int32)
        bounds = jnp.cumsum(gp)
        tg = jnp.minimum(
            jnp.searchsorted(bounds, jnp.arange(c // tile) * tile,
                             side="right"), s - 1).astype(jnp.int32)
        got = np.asarray(grouped_ffn_pallas(x, w, tg))
        want = np.asarray(grouped_matmul(x, w, gp, tg, "ragged"))
        total = int(gp.sum())
        np.testing.assert_allclose(got[:total], want[:total], rtol=1e-5,
                                   atol=1e-5)

    def test_cold_experts_never_referenced(self):
        """tile_group never points at groups with zero tokens, so their
        weights are never DMA'd — poisoning them must not change the
        output (the kernel-level METRO property)."""
        rng = np.random.default_rng(2)
        c, d, f, s, tile = 64, 128, 128, 8, 8
        x = jnp.asarray(rng.normal(size=(c, d)), jnp.float32)
        w = np.asarray(rng.normal(size=(s, d, f)) * 0.05, np.float32)
        tg = jnp.asarray([0, 0, 2, 2, 2, 5, 5, 5], jnp.int32)
        out1 = np.asarray(grouped_ffn_pallas(x, jnp.asarray(w), tg))
        w_poison = w.copy()
        for cold in (1, 3, 4, 6, 7):
            w_poison[cold] = np.nan
        out2 = np.asarray(grouped_ffn_pallas(x, jnp.asarray(w_poison), tg))
        np.testing.assert_array_equal(out1, out2)
