"""Flash-kernel wiring: ``EngineConfig.use_flash_kernel`` routes paged
decode attention through the Pallas ``flash_decode_paged`` kernel.

Layer-level parity (fast): ``attention_decode_paged(use_flash=True)``
matches the jnp gather reference to accumulation-order tolerance on the
SAME inputs — including the written-back pools being bitwise identical
(the write path is shared; only the read/softmax differs).  SWA layers
must ignore the flag (the decode kernel carries no window mask).

Engine-level (slow): a chunked+mixed engine with the flag on serves a
multi-request trace to completion, and every decode compile goes
through the kernel path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.models import layers as L
from repro.serving import EngineConfig, ServingEngine
from repro.serving.kv import PagedKVManager, pages_for
from repro.sharding.policy import make_dist


def _layer_setup(seed=0, b=3, ps=4, pmax=6, dtype=jnp.float32):
    """A full-attention layer + half-filled paged pools + one new
    token per row, shaped like the engine's decode step."""
    cfg = get_config("mixtral-8x22b").reduced()
    key = jax.random.PRNGKey(seed)
    kp, kx, kk, kv_ = jax.random.split(key, 4)
    params = L.init_attention(cfg, kp)
    dims = L.attn_dims(cfg)
    num_pages = 2 * b * pmax
    man = PagedKVManager(num_pages=num_pages, page_size=ps,
                         max_pages_per_seq=pmax, max_seqs=b)
    rng = np.random.default_rng(seed)
    pos = rng.integers(1, pmax * ps - 1, size=b).astype(np.int32)
    for s in range(b):
        assert man.ensure(s, int(pos[s]) + 1)
    pools = {
        "k": jax.random.normal(
            kk, (num_pages, ps, dims.kv, dims.head_dim)).astype(dtype),
        "v": jax.random.normal(
            kv_, (num_pages, ps, dims.kv, dims.head_dim)).astype(dtype),
    }
    x = jax.random.normal(kx, (b, 1, cfg.d_model), jnp.float32)
    pt = jnp.asarray(man.rows(np.arange(b)))
    return cfg, params, x, pools, pt, jnp.asarray(pos)


class TestLayerParity:
    pytestmark = pytest.mark.fast

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_flash_matches_gather_reference(self, seed):
        cfg, params, x, pools, pt, pos = _layer_setup(seed)
        o_ref, c_ref = L.attention_decode_paged(
            cfg, params, x, pools, pt, pos, use_flash=False)
        o_fl, c_fl = L.attention_decode_paged(
            cfg, params, x, pools, pt, pos, use_flash=True)
        # the K/V write path is shared: pools must be bitwise equal
        for k in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(c_ref[k]),
                                          np.asarray(c_fl[k]))
        # the read path differs only in softmax accumulation order
        np.testing.assert_allclose(np.asarray(o_fl), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-5)

    def test_flash_matches_reference_bf16_pools(self):
        """The serving pools are bf16: the kernel dequantizes
        in-register, the reference upcasts the gathered view — same
        stored values, looser accumulation tolerance."""
        cfg, params, x, pools, pt, pos = _layer_setup(
            3, dtype=jnp.bfloat16)
        o_ref, _ = L.attention_decode_paged(
            cfg, params, x, pools, pt, pos, use_flash=False)
        o_fl, _ = L.attention_decode_paged(
            cfg, params, x, pools, pt, pos, use_flash=True)
        np.testing.assert_allclose(
            np.asarray(o_fl, np.float32), np.asarray(o_ref, np.float32),
            rtol=2e-2, atol=2e-2)

    def test_swa_layers_ignore_the_flag(self):
        """window != None keeps the gather reference (the decode kernel
        has no sliding-window mask): identical outputs either way."""
        cfg, params, x, pools, pt, pos = _layer_setup(4)
        o_ref, _ = L.attention_decode_paged(
            cfg, params, x, pools, pt, pos, window=8, use_flash=False)
        o_fl, _ = L.attention_decode_paged(
            cfg, params, x, pools, pt, pos, window=8, use_flash=True)
        np.testing.assert_array_equal(np.asarray(o_ref),
                                      np.asarray(o_fl))


class TestEngineWiring:
    pytestmark = pytest.mark.slow

    def test_flash_engine_serves_to_completion(self):
        cfg = get_config("mixtral-8x22b").reduced()
        ep = 4
        spd = slots_for_ratio(cfg.num_experts, ep, 1.25)
        dist = make_dist(None, ep_size=ep, slots_per_device=spd)
        placement = build_placement(cfg.num_experts, ep, spd)
        params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                         replica_expert=placement.replica_expert)
        eng = ServingEngine(cfg, dist, params, EngineConfig(
            max_batch=4, max_len=64, rebalance_every=0,
            prefill_chunk=8, use_flash_kernel=True))
        rng = np.random.default_rng(0)
        for n in (5, 20, 9):
            eng.submit(rng.integers(0, cfg.vocab_size, n), 5)
        s = eng.run()
        assert s["requests"] == 3
        assert all(len(r.generated) == 5
                   for r in eng.completed.values())
        assert eng.kvman.pages_in_use == 0
