"""Property tests for the MoE dispatch buffer (the static-shape heart
of the EP datapath) and the grouped-matmul implementations.

The dead-tile contract (build_pair_buffer -> every impl): tiles with
zero live rows carry ``tile_group == -1``, are always trailing, cost no
weight DMA / FLOPs in the kernels, and their output rows are exact
zeros in every impl."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.moe import build_pair_buffer, grouped_matmul

_case = st.tuples(
    st.integers(1, 40),      # tokens
    st.integers(1, 4),       # k
    st.integers(1, 6),       # local slots
    st.integers(0, 12),      # total slots (lo offset room)
    st.integers(0, 2**31 - 1),
)


@settings(max_examples=60, deadline=None)
@given(_case)
def test_pair_buffer_invariants(case):
    t, k, s_loc, extra, seed = case
    rng = np.random.default_rng(seed)
    total_slots = s_loc + extra
    lo = extra // 2
    slots = rng.integers(-1, total_slots, (t, k)).astype(np.int32)
    tile = int(rng.choice([1, 2, 4, 8]))
    n_local = int(((slots >= lo) & (slots < lo + s_loc)).sum())
    capacity = ((n_local + s_loc * (tile - 1)) // tile + 1) * tile

    buf_pair, group_pad, tile_group, n_live = jax.jit(
        build_pair_buffer, static_argnames=("s_loc", "capacity", "tile")
    )(jnp.asarray(slots), lo, s_loc=s_loc, capacity=capacity, tile=tile)
    buf_pair = np.asarray(buf_pair)
    group_pad = np.asarray(group_pad)
    tile_group = np.asarray(tile_group)
    n_live = int(n_live)

    # 1. every local pair appears exactly once; non-local never
    placed = buf_pair[buf_pair >= 0]
    assert len(placed) == len(set(placed.tolist())) == n_local
    flat = slots.reshape(-1)
    for pidx in placed:
        assert lo <= flat[pidx] < lo + s_loc

    # 2. rows sit inside their slot's padded segment, in segment order
    bounds = np.concatenate([[0], np.cumsum(group_pad)])
    for row, pidx in enumerate(buf_pair):
        if pidx < 0:
            continue
        g = flat[pidx] - lo
        assert bounds[g] <= row < bounds[g + 1]

    # 3. tile alignment: group_pad multiples of tile; live tiles'
    #    tile_group constant within each segment
    assert (group_pad % tile == 0).all()
    for ti, g in enumerate(tile_group):
        if g < 0:
            continue
        start = ti * tile
        if start < bounds[-1]:
            # the tile lies fully inside group g's padded segment
            assert bounds[g] <= start and start + tile <= bounds[g + 1]

    # 4. dead-tile marking: -1 exactly on tiles with zero live rows,
    #    dead tiles are trailing, n_live counts the rest
    tile_live = (buf_pair >= 0).reshape(-1, tile).any(axis=1)
    np.testing.assert_array_equal(tile_group >= 0, tile_live)
    assert n_live == int(tile_live.sum())
    if n_live < len(tile_group):
        assert (tile_group[n_live:] == -1).all(), \
            "dead tiles must be trailing (kernel DMA-parking relies on it)"


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_grouped_matmul_impls_agree(seed):
    rng = np.random.default_rng(seed)
    s_loc = int(rng.integers(1, 5))
    tile = int(rng.choice([2, 4, 8]))
    gs = rng.integers(0, 4, s_loc) * tile          # tile-aligned sizes
    c = int(gs.sum() + tile * rng.integers(1, 3))  # slack
    d, f = 16, 24
    x = jnp.asarray(rng.normal(size=(c, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(s_loc, d, f)) * 0.1, jnp.float32)
    group_pad = jnp.asarray(gs, jnp.int32)
    bounds = np.cumsum(gs)
    tg = np.minimum(
        np.searchsorted(bounds, np.arange(c // tile) * tile, side="right"),
        s_loc - 1).astype(np.int32)
    tg[np.arange(c // tile) * tile >= gs.sum()] = -1   # dead slack tiles
    tgj = jnp.asarray(tg)

    outs = {impl: np.asarray(
        grouped_matmul(x, w, group_pad, tgj, impl))
        for impl in ("ragged", "scan_tiles", "onehot")}
    n = int(gs.sum())  # only real rows are defined
    np.testing.assert_allclose(outs["ragged"][:n], outs["onehot"][:n],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs["scan_tiles"][:n], outs["onehot"][:n],
                               rtol=1e-4, atol=1e-4)
    # dead-tile path: residual rows are exact zeros in every impl (the
    # seed's ragged impl dumped them into the last local expert; its
    # deterministic regression test lives in test_moe_fused.py, outside
    # this module's hypothesis gate)
    for impl, out in outs.items():
        assert np.all(out[n:] == 0), impl
