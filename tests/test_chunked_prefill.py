"""Chunked-prefill equivalence harness (the PR's lock-down suite).

The resumable chunk path (``apply_lm(mode="chunk_prefill")``) has an
exact reference semantics: ONE chunk call covering the whole prompt.
These tests pin the equivalence **bit-for-bit** — logits AND the KV
pages / mamba state left behind — for every mixer type (full attention,
sliding-window, mamba) under random prompts and random chunk splits
(hypothesis), plus deterministic fixed-split cases that run even on
minimal installs.

Chunk calls go through ONE jitted entry point per arch, so a chunk size
compiles once and every later split reuses it (token-at-a-time splits
are nearly free); the engine-level integration (mixed steps,
preemption) lives in tests/test_mixed_steps.py.

Also here (it needs an engine object but never jits a step): the
admission skip-ahead regression — once chunked prefill makes partial
admission safe, a page-blocked long prompt must not starve admissible
short prompts behind it.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm
from repro.models import lm as LM
from repro.serving import EngineConfig, ServingEngine
from repro.serving.kv import PagedKVManager, pages_for
from repro.sharding.policy import make_dist

pytestmark = pytest.mark.fast

MAX_LEN, PS = 32, 8

# one arch per mixer family: pure full-attention MoE, SWA+full
# interleave, pure mamba, and the mamba+attn+MoE hybrid
ARCHS = ["mixtral-8x22b", "gemma3-12b", "falcon-mamba-7b",
         "jamba-1.5-large-398b"]

_SETUP_CACHE: dict = {}


def _setup(name):
    if name in _SETUP_CACHE:
        return _SETUP_CACHE[name]
    cfg = get_config(name).reduced()
    ep = 4
    spd = slots_for_ratio(cfg.num_experts, ep, 1.25) if cfg.is_moe else 1
    dist = make_dist(None, ep_size=ep, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, ep, spd)
                 if cfg.is_moe else None)
    params = init_lm(cfg, jax.random.PRNGKey(0), dist,
                     replica_expert=placement.replica_expert
                     if placement else None)
    routing = (LM.build_lm_routing(cfg, placement)
               if cfg.is_moe else {})
    _SETUP_CACHE[name] = (cfg, dist, params, routing)
    return _SETUP_CACHE[name]


_FN_CACHE: dict = {}


def _chunk_call(name, algo):
    """Jitted chunk_prefill entry per (arch, algo): a chunk size
    compiles once and is reused for every split that needs it."""
    key = (name, algo)
    if key not in _FN_CACHE:
        cfg, dist, _, _ = _setup(name)

        @jax.jit
        def fn(params, routing, toks, start, slot_idx, pt, rv, cache):
            lg, cache, _ = LM.apply_lm(
                cfg, dist, params, tokens=toks, pos=start, cache=cache,
                routing=routing, mode="chunk_prefill", algo=algo,
                slot_idx=slot_idx, page_table=pt, row_valid=rv)
            return lg, cache
        _FN_CACHE[key] = fn
    return _FN_CACHE[key]


def _run_split(name, prompt, splits, algo="eplb"):
    """Prefill ``prompt`` through the chunk path in the given splits.

    Returns (logits [n, V] over all real positions, cache leaves)."""
    cfg, dist, params, routing = _setup(name)
    fn = _chunk_call(name, algo)
    pmax = pages_for(MAX_LEN, PS)
    man = PagedKVManager(num_pages=2 * pmax, page_size=PS,
                         max_pages_per_seq=pmax, max_seqs=2)
    cache = LM.init_paged_cache(cfg, dist, 2 * pmax, PS, 2)
    pos, logits_all = 0, []
    for c in splits:
        toks = np.asarray(prompt[pos:pos + c], np.int32)[None, :]
        assert man.ensure(0, pos + c)
        lg, cache = fn(
            params, routing, jax.numpy.asarray(toks),
            jax.numpy.asarray([pos], np.int32),
            jax.numpy.asarray([0], np.int32),
            jax.numpy.asarray(man.rows([0])),
            jax.numpy.ones((1, c), bool), cache)
        logits_all.append(np.asarray(lg[0]))
        pos += c
    return (np.concatenate(logits_all, 0),
            [np.asarray(x) for x in jax.tree.leaves(cache)])


def _assert_bitexact(name, prompt, splits):
    n = len(prompt)
    lg_mono, cache_mono = _run_split(name, prompt, [n])
    lg, cache = _run_split(name, prompt, splits)
    np.testing.assert_array_equal(
        lg, lg_mono,
        err_msg=f"{name}: chunk split {splits} drifted from monolithic "
                "prefill logits")
    for a, b in zip(cache, cache_mono):
        np.testing.assert_array_equal(
            a, b,
            err_msg=f"{name}: split {splits} left different KV/state")


class TestChunkedEqualsMonolithic:
    @pytest.mark.parametrize("name", ARCHS)
    def test_fixed_splits_bitexact(self, name):
        """Deterministic anchor (no hypothesis needed): token-at-a-time,
        even, and ragged splits all reproduce the monolithic call."""
        rng = np.random.default_rng(0)
        cfg = _setup(name)[0]
        n = 13
        prompt = rng.integers(0, cfg.vocab_size, n)
        for splits in ([1] * n, [4, 4, 4, 1], [3, 10], [12, 1]):
            _assert_bitexact(name, prompt, splits)

    @pytest.mark.parametrize("name", ARCHS)
    def test_random_splits_bitexact(self, name):
        """Hypothesis property: ANY chunk split of ANY prompt is
        bit-exact vs a single monolithic prefill call (logits and KV
        pages), for every mixer type."""
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        cfg = _setup(name)[0]

        @st.composite
        def case(draw):
            # n capped at 16 to bound the per-size compile set (chunk
            # sizes 1..16 amortize across the whole hypothesis run)
            n = draw(st.integers(1, 16))
            prompt = draw(st.lists(
                st.integers(0, cfg.vocab_size - 1),
                min_size=n, max_size=n))
            splits, left = [], n
            while left > 0:
                c = draw(st.integers(1, left))
                splits.append(c)
                left -= c
            return np.asarray(prompt, np.int32), splits

        @given(case())
        @settings(deadline=None)   # examples come from the active profile
        def prop(pc):
            prompt, splits = pc
            _assert_bitexact(name, prompt, splits)

        prop()

    def test_prefill_algo_does_not_change_chunk_logits(self):
        """Replica choice (METRO vs EPLB) moves compute, not math — the
        chunk path must keep that invariant."""
        rng = np.random.default_rng(1)
        cfg = _setup("mixtral-8x22b")[0]
        prompt = rng.integers(0, cfg.vocab_size, 11)
        lg_e, cache_e = _run_split("mixtral-8x22b", prompt, [5, 6],
                                   algo="eplb")
        lg_m, cache_m = _run_split("mixtral-8x22b", prompt, [5, 6],
                                   algo="metro")
        np.testing.assert_array_equal(lg_e, lg_m)
        for a, b in zip(cache_e, cache_m):
            np.testing.assert_array_equal(a, b)


class TestAdmissionSkipAhead:
    """Chunked prefill makes partial admission safe: `_admit` only needs
    pages for a request's FIRST chunk, and a page-blocked request must
    not head-of-line block admissible requests behind it."""

    def _engine(self, **kw):
        cfg, dist, params, _ = _setup("mixtral-8x22b")
        ecfg = EngineConfig(**{
            "max_batch": 4, "max_len": 64, "page_size": 8,
            "prefill_chunk": 32, "rebalance_every": 0, **kw})
        return cfg, ServingEngine(cfg, dist, params, ecfg)

    def test_short_prompt_admits_past_blocked_long_prompt(self):
        cfg, eng = self._engine(num_pages=8)
        # occupy 6 of 8 pages so only 2 are free
        assert eng.kvman.ensure(3, 48)
        eng.free_slots.remove(3)
        rng = np.random.default_rng(0)
        rid_long = eng.submit(rng.integers(0, cfg.vocab_size, 40), 4)
        rid_short = eng.submit(rng.integers(0, cfg.vocab_size, 10), 4)
        admitted = eng._admit()
        # long prompt's first chunk needs 4 pages > 2 free -> blocked;
        # the short one (2 pages) is admitted past it
        assert [r.rid for r in admitted] == [rid_short]
        assert [r.rid for r in eng.queue] == [rid_long]   # order kept
        assert rid_short in eng.active

    def test_wave_mode_keeps_strict_fcfs(self):
        """The seed's head-of-line gate is preserved for A/B: in wave
        mode the same scenario admits nothing."""
        cfg, eng = self._engine(num_pages=8, prefill_mode="wave")
        assert eng.kvman.ensure(3, 48)
        eng.free_slots.remove(3)
        rng = np.random.default_rng(0)
        eng.submit(rng.integers(0, cfg.vocab_size, 40), 4)
        eng.submit(rng.integers(0, cfg.vocab_size, 10), 4)
        assert eng._admit() == []
        assert len(eng.queue) == 2

    def test_admission_reserves_first_chunk_only(self):
        cfg, eng = self._engine()
        rng = np.random.default_rng(1)
        eng.submit(rng.integers(0, cfg.vocab_size, 50), 4)
        (r,) = eng._admit()
        # 50-token prompt, 32-token chunk, 8-token pages: 4 pages now,
        # the rest reserved chunk-by-chunk as prefill advances
        assert eng.kvman.owned(r.slot) == 4
        eng.kvman.check_consistent()
