"""SLOTracker unit tests on hand-built timelines via an injected clock.

Every derived quantity — TTFT/TPOT percentiles, the TTFT decomposition
(queue wait / prefill span / decode wait), per-kind step and compile
counters, and the chunk-stall attribution — must be deterministic and
exactly computable from the event timeline, with no real wall clock in
the loop.
"""
import numpy as np
import pytest

from repro.serving import SLOTracker

pytestmark = pytest.mark.fast


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _tracker():
    clk = Clock()
    return SLOTracker(clock=clk), clk


class TestRequestTimeline:
    def test_ttft_and_tpot_exact(self):
        slo, clk = _tracker()
        slo.arrive(0, n_prompt=10)          # t=0
        clk.t = 1.0
        slo.first_token(0)                  # TTFT = 1.0
        for t in (1.5, 2.0, 2.5):
            clk.t = t
            slo.token(0)
        clk.t = 2.5
        slo.finish(0)
        s = slo.summary()
        assert s["requests"] == 1
        assert s["ttft_mean"] == pytest.approx(1.0)
        # 4 generated tokens over (2.5 - 1.0) -> TPOT = 0.5
        assert s["tpot_mean"] == pytest.approx(0.5)

    def test_percentiles_match_numpy(self):
        slo, clk = _tracker()
        ttfts = [0.1, 0.2, 0.4, 0.8, 1.6]
        for rid, ttft in enumerate(ttfts):
            clk.t = float(rid) * 10
            slo.arrive(rid, 5)
            clk.t = rid * 10 + ttft
            slo.first_token(rid)
            slo.finish(rid)
        s = slo.summary()
        for q in (50, 90, 99):
            assert s[f"ttft_p{q}"] == pytest.approx(
                np.percentile(ttfts, q))

    def test_ttft_decomposition(self):
        """queue wait + prefill span + decode wait == TTFT when the
        engine emits every chunk-boundary event."""
        slo, clk = _tracker()
        slo.arrive(0, 64)                   # t=0
        clk.t = 0.25
        slo.admitted(0)                     # queue_wait = 0.25
        clk.t = 0.25
        slo.prefill_started(0)
        for t in (0.5, 0.75, 1.0):          # three chunks
            clk.t = t
            slo.chunk_done(0)
        clk.t = 1.0
        slo.prefill_done(0)                 # prefill_span = 0.75
        clk.t = 1.5
        slo.first_token(0)                  # decode_wait = 0.5
        clk.t = 2.0
        slo.finish(0)
        s = slo.summary()
        assert s["ttft_queue_mean"] == pytest.approx(0.25)
        assert s["ttft_prefill_mean"] == pytest.approx(0.75)
        assert s["ttft_decode_wait_mean"] == pytest.approx(0.5)
        assert (s["ttft_queue_mean"] + s["ttft_prefill_mean"]
                + s["ttft_decode_wait_mean"]) == pytest.approx(
                    s["ttft_mean"])
        assert s["prefill_chunks"] == 3

    def test_prefill_start_is_sticky_across_recompute(self):
        """Readmission after preemption re-runs chunks; the FIRST
        prefill_started timestamp must survive (TTFT is end-to-end)."""
        slo, clk = _tracker()
        slo.arrive(0, 8)
        clk.t = 1.0
        slo.prefill_started(0)
        clk.t = 5.0
        slo.prefill_started(0)              # recompute: ignored
        slo.chunk_done(0)
        clk.t = 6.0
        slo.prefill_done(0)
        clk.t = 6.5
        slo.first_token(0)
        slo.finish(0)
        t = slo.timings[0]
        assert t.prefill_start == pytest.approx(1.0)
        assert t.prefill_span == pytest.approx(5.0)


class TestCounters:
    def test_per_kind_compiles(self):
        slo, _ = _tracker()
        slo.compiled("decode", 4)
        slo.compiled("decode", 8)
        slo.compiled("chunk", 2)
        slo.compiled("mixed", (2, 4))
        assert slo.compile_count("decode") == 2
        assert slo.compile_count("chunk") == 1
        assert slo.compile_count("mixed") == 1
        assert slo.compile_count("prefill") == 0
        assert slo.total_compiles == 4

    def test_step_kinds_counted(self):
        slo, clk = _tracker()
        slo.arrive(0, 4)
        clk.t = 1.0
        slo.first_token(0)
        slo.finish(0)
        slo.step("chunk", 0.1)
        slo.step("mixed", 0.2)
        slo.step("mixed", 0.3)
        slo.step("decode", 0.05)
        s = slo.summary()
        assert s["chunk_steps"] == 1
        assert s["mixed_steps"] == 2
        assert s["decode_steps"] == 1
        assert s["mixed_step_p99_s"] == pytest.approx(
            np.percentile([0.2, 0.3], 99))

    def test_stall_attribution(self):
        """Chunk-stall accounting: total/max/count over exactly the
        seconds the engine reported decode rows waiting."""
        slo, clk = _tracker()
        slo.arrive(0, 4)
        clk.t = 1.0
        slo.first_token(0)
        slo.finish(0)
        slo.stall("chunk", 0.2)
        slo.stall("chunk", 0.1)
        slo.stall("prefill", 0.7)
        s = slo.summary()
        assert s["decode_stall_events"] == 3
        assert s["decode_stall_total_s"] == pytest.approx(1.0)
        assert s["decode_stall_max_s"] == pytest.approx(0.7)

    def test_no_events_is_clean(self):
        slo, clk = _tracker()
        slo.arrive(0, 4)
        clk.t = 1.0
        slo.first_token(0)
        slo.finish(0)
        s = slo.summary()
        assert s["decode_stall_events"] == 0
        assert s["decode_stall_total_s"] == 0.0
        assert s["prefill_chunks"] == 0
        assert s["expert_pool_hits"] == 0
        assert s["expert_pool_hit_rate"] == 0.0
        assert s["expert_prefetch_coverage"] == 0.0
        assert s["expert_stall_events"] == 0


def _finished_tracker():
    """A tracker with one finished request so summary() is non-empty."""
    slo, clk = _tracker()
    slo.arrive(0, 4)
    clk.t = 1.0
    slo.first_token(0)
    slo.finish(0)
    return slo, clk


class TestExpertPool:
    def test_counters_and_ratios_accumulate(self):
        slo, _ = _finished_tracker()
        slo.expert_pool_access(hits=3, misses=1, planned_hits=2)
        slo.expert_pool_access(hits=5, misses=3, planned_hits=4)
        s = slo.summary()
        assert s["expert_pool_hits"] == 8
        assert s["expert_pool_misses"] == 4
        assert s["expert_pool_hit_rate"] == pytest.approx(8 / 12)
        # coverage counts pages the previous plan named, resident or
        # not — a different numerator than the hit rate
        assert s["expert_prefetch_coverage"] == pytest.approx(6 / 12)

    def test_miss_stall_lands_in_both_accounts(self):
        """An expert demand-miss stall is a decode stall (generic
        account) AND an expert stall (its own attribution)."""
        slo, _ = _finished_tracker()
        slo.expert_pool_access(hits=0, misses=2, stall_s=0.3)
        slo.stall("expert_gate", 0.1)       # scheduler residency gate
        slo.stall("chunk", 0.5)             # unrelated decode stall
        s = slo.summary()
        assert s["expert_stall_events"] == 2
        assert s["expert_stall_total_s"] == pytest.approx(0.4)
        assert s["expert_stall_max_s"] == pytest.approx(0.3)
        assert s["decode_stall_events"] == 3
        assert s["decode_stall_total_s"] == pytest.approx(0.9)

    def test_zero_stall_records_no_event(self):
        slo, _ = _finished_tracker()
        slo.expert_pool_access(hits=1, misses=0, stall_s=0.0)
        s = slo.summary()
        assert s["expert_stall_events"] == 0
        assert s["expert_pool_hits"] == 1

    def test_cluster_rollup_recomputes_ratios(self):
        """Pooled hit rate comes from pooled counts, not an average of
        per-replica ratios (an unevenly loaded replica would skew an
        average; pooled counts weight by traffic)."""
        from repro.serving import aggregate_cluster_summary
        trackers = []
        for hits, misses, planned, stall in ((19, 1, 10, 0.2),
                                             (1, 9, 2, 0.7)):
            slo, clk = _tracker()
            slo.arrive(0, 4)
            clk.t = 1.0
            slo.first_token(0)
            slo.finish(0)
            slo.expert_pool_access(hits=hits, misses=misses,
                                   planned_hits=planned, stall_s=stall)
            trackers.append(slo)
        agg = aggregate_cluster_summary(trackers)
        assert agg["expert_pool_hits"] == 20
        assert agg["expert_pool_misses"] == 10
        assert agg["expert_pool_hit_rate"] == pytest.approx(2 / 3)
        per_replica_mean = np.mean([19 / 20, 1 / 10])
        assert agg["expert_pool_hit_rate"] != pytest.approx(
            per_replica_mean)
        assert agg["expert_prefetch_coverage"] == pytest.approx(12 / 30)
        assert agg["expert_stall_events"] == 2
        assert agg["expert_stall_total_s"] == pytest.approx(0.9)
