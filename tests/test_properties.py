"""Property-based tests (hypothesis) for the routing system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    build_placement, route_metro, route_eplb, metro_token_slots,
    topk_histogram, routing_stats, solve_min_exp_routing,
)

# keep cases small: the oracle is O(maxflow) python
_cfg = st.tuples(
    st.integers(2, 24),   # experts
    st.integers(2, 8),    # devices
    st.integers(1, 4),    # extra replication slots factor numerator
    st.integers(0, 2**31 - 1),  # seed
)


def _mk(n, g, extra, seed):
    rng = np.random.default_rng(seed)
    s = max(int(np.ceil(n / g)), 1) + extra % 3
    loads = rng.random(n) + 0.01
    p = build_placement(n, g, s, loads=loads)
    batch = int(rng.integers(1, 64))
    k = int(rng.integers(1, min(4, n) + 1))
    ids = rng.integers(0, n, (batch, k)).astype(np.int32)
    return p, jnp.asarray(ids)


@settings(max_examples=40, deadline=None)
@given(_cfg)
def test_metro_no_token_drops_and_placement_respected(cfg):
    n, g, extra, seed = cfg
    p, ids = _mk(n, g, extra, seed)
    t = topk_histogram(ids, n)
    es = np.asarray(route_metro(t, jnp.asarray(p.expert_slots),
                                num_devices=g,
                                slots_per_device=p.slots_per_device))
    tn = np.asarray(t)
    for e in range(n):
        if tn[e] > 0:
            assert es[e] >= 0, "active expert must be routed (no drops)"
            assert p.replica_expert[es[e]] == e, "must route to own replica"
        else:
            assert es[e] == -1


@settings(max_examples=40, deadline=None)
@given(_cfg)
def test_metro_lemma1(cfg):
    n, g, extra, seed = cfg
    p, ids = _mk(n, g, extra, seed)
    t = topk_histogram(ids, n)
    es = route_metro(t, jnp.asarray(p.expert_slots),
                     num_devices=g, slots_per_device=p.slots_per_device)
    slots = np.asarray(metro_token_slots(ids, es))
    idn = np.asarray(ids)
    for e in range(n):
        used = np.unique(slots[idn == e])
        assert len(used) <= 1, "Lemma 1: one replica per expert"


@settings(max_examples=25, deadline=None)
@given(_cfg)
def test_metro_within_2x_of_optimal(cfg):
    """Greedy list-scheduling bound for restricted machines: the greedy
    lambda is provably <= 2x optimal; empirically (paper Fig. 8) it is
    within ~11%. We assert the hard bound and track the soft one."""
    n, g, extra, seed = cfg
    p, ids = _mk(n, g, extra, seed)
    t = topk_histogram(ids, n)
    es = route_metro(t, jnp.asarray(p.expert_slots),
                     num_devices=g, slots_per_device=p.slots_per_device)
    slots = metro_token_slots(ids, es)
    lam_greedy = routing_stats(slots, p).max_activated
    lam_opt, _ = solve_min_exp_routing(np.asarray(t), p.placement_matrix())
    assert lam_opt <= lam_greedy <= max(2 * lam_opt, lam_opt + 1)


@settings(max_examples=40, deadline=None)
@given(_cfg)
def test_eplb_respects_placement_and_balance(cfg):
    n, g, extra, seed = cfg
    p, ids = _mk(n, g, extra, seed)
    slots = np.asarray(route_eplb(ids, jnp.asarray(p.expert_slots),
                                  jnp.asarray(p.expert_num_replicas)))
    idn = np.asarray(ids)
    for (b, k), s in np.ndenumerate(slots):
        assert s >= 0
        assert p.replica_expert[s] == idn[b, k]
    # per-expert replica usage is balanced within 1 token
    for e in range(n):
        mask = idn == e
        if mask.sum() == 0:
            continue
        used, counts = np.unique(slots[mask], return_counts=True)
        n_rep = int(p.expert_num_replicas[e])
        if mask.sum() >= n_rep:
            assert len(used) == n_rep, "EPLB must spread across all replicas"
        assert counts.max() - counts.min() <= 1


@settings(max_examples=40, deadline=None)
@given(_cfg)
def test_metro_never_more_activated_than_eplb_max(cfg):
    """METRO's objective: its lambda is <= EPLB's on the same instance.

    (Not a theorem in general for *any* greedy order, but holds whenever
    replication > 1 forces EPLB to split; we assert the weak direction
    that is the paper's core claim on expectation: metro <= eplb.)"""
    n, g, extra, seed = cfg
    p, ids = _mk(n, g, extra, seed)
    t = topk_histogram(ids, n)
    es = route_metro(t, jnp.asarray(p.expert_slots),
                     num_devices=g, slots_per_device=p.slots_per_device)
    m = routing_stats(metro_token_slots(ids, es), p).max_activated
    e = routing_stats(
        route_eplb(ids, jnp.asarray(p.expert_slots),
                   jnp.asarray(p.expert_num_replicas)), p).max_activated
    # EPLB activates every replica of every active expert; METRO one per
    # expert. Per-device max can in principle tie, never undercut METRO
    # by more than the greedy gap; assert the paper's direction with the
    # 2x greedy slack.
    assert m <= max(e * 2, e + 1)
