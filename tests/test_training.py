"""Training substrate: optimizer, checkpoint/restart fault tolerance,
data pipeline determinism, loss-goes-down."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_placement, slots_for_ratio
from repro.data.pipeline import DataConfig, make_dataset
from repro.launch.steps import StepConfig
from repro.sharding.policy import make_dist
from repro.training import checkpoint as CKPT
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.train_loop import TrainConfig, train


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw_init(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_bf16_moments(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        params = {"w": jnp.zeros((4,), jnp.float32)}
        st = adamw_init(params, cfg)
        assert st["mu"]["w"].dtype == jnp.bfloat16

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0,
                          warmup_steps=1)
        params = {"w": jnp.zeros((2,))}
        st = adamw_init(params, cfg)
        p2, _, m = adamw_update(cfg, {"w": jnp.array([1e6, 1e6])}, st,
                                params)
        assert m["grad_norm"] > 1e5
        assert float(jnp.abs(p2["w"]).max()) < 10.0


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=4)
        ds = make_dataset(cfg)
        a, b = ds(7), ds(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_distinct_steps_and_hosts(self):
        cfg0 = DataConfig(vocab_size=256, seq_len=32, global_batch=4,
                          num_hosts=2, host_id=0)
        cfg1 = DataConfig(vocab_size=256, seq_len=32, global_batch=4,
                          num_hosts=2, host_id=1)
        d0, d1 = make_dataset(cfg0), make_dataset(cfg1)
        assert not np.array_equal(d0(3)["tokens"], d1(3)["tokens"])
        assert not np.array_equal(d0(3)["tokens"], d0(4)["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=2)
        b = make_dataset(cfg)(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["labels"][:, :-1])


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.zeros((4,), jnp.int32)}}
        CKPT.save(tmp_path, 10, tree)
        got, meta = CKPT.restore(tmp_path, tree)
        assert meta["step"] == 10
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(tree["a"]))

    def test_keep_k_gc(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        for s in (1, 2, 3, 4, 5):
            CKPT.save(tmp_path, s, tree, keep=2)
        steps = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(steps) == 2
        assert CKPT.latest_step(tmp_path) == 5

    def test_shape_mismatch_rejected(self, tmp_path):
        CKPT.save(tmp_path, 1, {"a": jnp.zeros((2, 2))})
        with pytest.raises(AssertionError):
            CKPT.restore(tmp_path, {"a": jnp.zeros((3, 3))})


class TestTrainLoop:
    def _cfg(self):
        cfg = get_config("qwen2-moe-a2.7b").reduced()
        ep = 4
        spd = slots_for_ratio(cfg.num_experts, ep, 1.0)
        dist = make_dist(None, ep_size=ep, slots_per_device=spd)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                        global_batch=4)
        return cfg, dist, dc

    def test_loss_decreases(self, tmp_path):
        cfg, dist, dc = self._cfg()
        tc = TrainConfig(total_steps=50, ckpt_every=1000,
                         ckpt_dir=str(tmp_path), log_every=1000)
        sc = StepConfig(cfg=cfg, dist=dist, remat=False, fsdp=False,
                        opt=AdamWConfig(lr=1e-2, warmup_steps=5,
                                        weight_decay=0.0))
        _, _, hist = train(cfg, dist, dc, tc, sc=sc, verbose=False)
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.3, f"loss did not improve: {first}->{last}"

    def test_restart_is_bitwise_identical(self, tmp_path):
        """Kill at step 12, resume, final params == uninterrupted run."""
        cfg, dist, dc = self._cfg()

        class Die(Exception):
            pass

        tc1 = TrainConfig(total_steps=20, ckpt_every=5,
                          ckpt_dir=str(tmp_path / "a"), log_every=1000)
        hooks = {12: lambda *a: (_ for _ in ()).throw(Die())}
        with pytest.raises(Die):
            train(cfg, dist, dc, tc1, hooks=hooks, verbose=False)
        # resume (simulates node failure + restart from step 10)
        p1, o1, _ = train(cfg, dist, dc, tc1, verbose=False)

        tc2 = TrainConfig(total_steps=20, ckpt_every=5,
                          ckpt_dir=str(tmp_path / "b"), log_every=1000)
        p2, o2, _ = train(cfg, dist, dc, tc2, verbose=False)

        flat1 = jax.tree.leaves(p1)
        flat2 = jax.tree.leaves(p2)
        for x, y in zip(flat1, flat2):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
