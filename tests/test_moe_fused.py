"""Fused expert-FFN megakernel: parity sweeps, dead-tile skip contract,
HBM-traffic/DMA accounting, per-call interpret-mode selection, and the
engine-level moe_impl="fused" serve equivalence.

The fused kernel (kernels/moe_ffn.fused_expert_ffn_pallas) runs
up→act→down in one pass with the hidden resident in VMEM; its output
must match the ref.py oracle and the two-pass datapath on live rows and
be exact zeros on dead tiles."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.moe_ffn import fused_expert_ffn_pallas, grouped_ffn_pallas
from repro.models.moe import build_pair_buffer, grouped_matmul
from repro.sim.roofline import expert_ffn_traffic, fused_weight_dma_tiles

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _build(rng, t, k, s_loc, tile, *, short_capacity=False,
           all_remote=False):
    """Random routing -> pair buffer (optionally capacity-dropping or
    with zero local pairs)."""
    lo_draw = -1 if not all_remote else s_loc
    hi_draw = s_loc + 2
    slots = rng.integers(lo_draw, hi_draw, (t, k)).astype(np.int32)
    if all_remote:
        assert ((slots < 0) | (slots >= s_loc)).all()
    n_local = int(((slots >= 0) & (slots < s_loc)).sum())
    if short_capacity:
        capacity = max(tile, (max(n_local // 2, 1) // tile) * tile)
    else:
        capacity = ((n_local + s_loc * (tile - 1)) // tile + 2) * tile
    bp, gp, tg, nl = jax.jit(
        build_pair_buffer, static_argnames=("s_loc", "capacity", "tile")
    )(jnp.asarray(slots), 0, s_loc=s_loc, capacity=capacity, tile=tile)
    return (np.asarray(bp), np.asarray(gp), np.asarray(tg), int(nl),
            capacity)


def _two_pass_ref(x, wu, wd, tile_group, *, gated):
    """Composite oracle: two grouped_matmul_ref passes + gating, dead
    rows zeroed (grouped_matmul_ref predates the -1 convention)."""
    tile = x.shape[0] // len(tile_group)
    tg = np.maximum(tile_group, 0)
    h = ref.grouped_matmul_ref(x, wu, tg)
    fe = wd.shape[1]
    if gated:
        g, u = h[:, :fe], h[:, fe:]
        h = g / (1.0 + np.exp(-g)) * u
    else:
        h = 0.5 * h * (1.0 + np.tanh(
            np.sqrt(2.0 / np.pi) * (h + 0.044715 * h ** 3)))
    y = ref.grouped_matmul_ref(h, wd, tg)
    y[np.repeat(tile_group, tile) < 0] = 0.0
    return y


class TestFusedKernelParity:
    @pytest.mark.parametrize("gated", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracles(self, gated, dtype):
        """Fused == ref oracle == two-pass ref == ragged/onehot impls
        on live rows; exact zeros on dead tiles."""
        rng = np.random.default_rng(0)
        for seed in range(4):
            rng = np.random.default_rng(seed)
            t, k, s_loc = 11, 2, 3
            tile = int(rng.choice([2, 4, 8]))
            bp, gp, tg, nl, capacity = _build(rng, t, k, s_loc, tile)
            d, fe = 16, 24
            n_up = 2 if gated else 1
            x = jnp.asarray(rng.normal(size=(capacity, d)), dtype)
            wu = jnp.asarray(
                rng.normal(size=(s_loc, d, n_up * fe)) * 0.2, dtype)
            wd = jnp.asarray(
                rng.normal(size=(s_loc, fe, d)) * 0.2, dtype)
            got = np.asarray(fused_expert_ffn_pallas(
                x, wu, wd, jnp.asarray(tg), gated=gated,
                tile_k_up=8, tile_k_dn=8), np.float32)
            xf, uf, df = (np.asarray(a, np.float32) for a in (x, wu, wd))
            want = ref.fused_expert_ffn_ref(xf, uf, df, tg, gated=gated)
            want2 = _two_pass_ref(xf, uf, df, tg, gated=gated)
            tol = dict(rtol=5e-2, atol=5e-2) \
                if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)
            np.testing.assert_allclose(got, want, **tol)
            np.testing.assert_allclose(want2, want, rtol=2e-4, atol=2e-4)
            # the ragged two-pass datapath (the layer's default impl)
            fe_ = fe
            h = grouped_matmul(x, wu, jnp.asarray(gp), jnp.asarray(tg),
                               "ragged")
            if gated:
                h = jax.nn.silu(h[:, :fe_]) * h[:, fe_:]
            else:
                h = jax.nn.gelu(h)
            ragged = np.asarray(grouped_matmul(
                h.astype(dtype), wd, jnp.asarray(gp), jnp.asarray(tg),
                "ragged"), np.float32)
            live_rows = bp >= 0
            np.testing.assert_allclose(got[live_rows], ragged[live_rows],
                                       **tol)
            # dead tiles: exact zeros (not merely small)
            dead_rows = np.repeat(tg, tile) < 0
            assert np.all(got[dead_rows] == 0)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis")
    def test_hypothesis_sweep(self):
        @settings(deadline=None)
        @given(st.integers(0, 2**31 - 1), st.booleans(), st.booleans(),
               st.booleans())
        def inner(seed, gated, bf16, short_capacity):
            rng = np.random.default_rng(seed)
            t = int(rng.integers(1, 14))
            k = int(rng.integers(1, 4))
            s_loc = int(rng.integers(1, 5))
            tile = int(rng.choice([2, 4, 8]))
            bp, gp, tg, nl, capacity = _build(
                rng, t, k, s_loc, tile, short_capacity=short_capacity)
            d, fe = 8, 12
            n_up = 2 if gated else 1
            dtype = jnp.bfloat16 if bf16 else jnp.float32
            x = jnp.asarray(rng.normal(size=(capacity, d)), dtype)
            wu = jnp.asarray(
                rng.normal(size=(s_loc, d, n_up * fe)) * 0.2, dtype)
            wd = jnp.asarray(
                rng.normal(size=(s_loc, fe, d)) * 0.2, dtype)
            got = np.asarray(fused_expert_ffn_pallas(
                x, wu, wd, jnp.asarray(tg), gated=gated), np.float32)
            xf, uf, df = (np.asarray(a, np.float32)
                          for a in (x, wu, wd))
            want = ref.fused_expert_ffn_ref(xf, uf, df, tg, gated=gated)
            tol = dict(rtol=6e-2, atol=6e-2) if bf16 \
                else dict(rtol=2e-5, atol=2e-5)
            np.testing.assert_allclose(got, want, **tol)
            assert np.all(got[np.repeat(tg, tile) < 0] == 0)
        inner()

    def test_all_dead_batch(self):
        """Zero local pairs: every tile dead, output all-zero, and the
        traffic model charges the fused path nothing."""
        rng = np.random.default_rng(5)
        bp, gp, tg, nl, capacity = _build(rng, 9, 2, 3, 4,
                                          all_remote=True)
        assert nl == 0 and (tg == -1).all()
        d, fe = 8, 12
        x = jnp.asarray(rng.normal(size=(capacity, d)), jnp.float32)
        wu = jnp.asarray(np.full((3, d, 2 * fe), np.nan), jnp.float32)
        wd = jnp.asarray(np.full((3, fe, d), np.nan), jnp.float32)
        got = np.asarray(fused_expert_ffn_pallas(
            x, wu, wd, jnp.asarray(tg), gated=True))
        assert np.all(got == 0)
        tr = expert_ffn_traffic("fused", d=d, fe=fe, n_up=2, tile_m=4,
                                n_tiles=len(tg), live_tiles=0)
        assert tr["total"] == 0.0

    def test_etp_sharded_fe_partials_sum(self):
        """ETP shards fe: running the fused kernel per fe-shard and
        psum-ing the partial outputs == the unsharded kernel (the
        features-mode decode datapath)."""
        rng = np.random.default_rng(6)
        bp, gp, tg, nl, capacity = _build(rng, 10, 2, 3, 4)
        d, fe, shards = 8, 24, 2
        fs = fe // shards
        x = jnp.asarray(rng.normal(size=(capacity, d)), jnp.float32)
        wu = jnp.asarray(rng.normal(size=(3, d, 2 * fe)) * 0.2,
                         jnp.float32)
        wd = jnp.asarray(rng.normal(size=(3, fe, d)) * 0.2, jnp.float32)
        full = np.asarray(fused_expert_ffn_pallas(
            x, wu, wd, jnp.asarray(tg), gated=True))
        partial = np.zeros_like(full)
        for s in range(shards):
            # gate/up halves are each fe wide: take shard s of both
            wu_s = jnp.concatenate(
                [wu[:, :, s * fs:(s + 1) * fs],
                 wu[:, :, fe + s * fs:fe + (s + 1) * fs]], axis=-1)
            wd_s = wd[:, s * fs:(s + 1) * fs, :]
            partial += np.asarray(fused_expert_ffn_pallas(
                x, wu_s, wd_s, jnp.asarray(tg), gated=True))
        np.testing.assert_allclose(partial, full, rtol=2e-5, atol=2e-5)

    def test_cold_and_dead_expert_weights_never_touched(self):
        """Poisoning every expert no live tile references (including
        the groups dead tiles would have used) must not change the
        output — the kernel never DMAs them."""
        rng = np.random.default_rng(7)
        d, fe, s_loc, tile = 8, 12, 6, 4
        capacity = 6 * tile
        x = jnp.asarray(rng.normal(size=(capacity, d)), jnp.float32)
        wu = np.asarray(rng.normal(size=(s_loc, d, 2 * fe)) * 0.2,
                        np.float32)
        wd = np.asarray(rng.normal(size=(s_loc, fe, d)) * 0.2,
                        np.float32)
        tg = jnp.asarray([0, 0, 3, 3, -1, -1], jnp.int32)
        base = np.asarray(fused_expert_ffn_pallas(
            x, jnp.asarray(wu), jnp.asarray(wd), tg, gated=True))
        for cold in (1, 2, 4, 5):
            wu[cold] = np.nan
            wd[cold] = np.nan
        poisoned = np.asarray(fused_expert_ffn_pallas(
            x, jnp.asarray(wu), jnp.asarray(wd), tg, gated=True))
        np.testing.assert_array_equal(base, poisoned)


class TestGroupedImplsWithDeadTiles:
    def test_ragged_residual_not_charged_to_last_group(self):
        """The ragged impl must route residual capacity to the
        dead-tile path: poisoning EVERY expert's weights cannot leak
        into the residual rows (they belong to no group).  Regression
        for the seed impl's ``group_pad.at[s_loc-1].add(...)``."""
        rng = np.random.default_rng(0)
        s_loc, tile, d, f = 3, 4, 8, 8
        gs = np.array([4, 8, 4], np.int32)
        c = int(gs.sum()) + 2 * tile               # 2 dead slack tiles
        x = jnp.asarray(rng.normal(size=(c, d)), jnp.float32)
        w = jnp.asarray(np.full((s_loc, d, f), np.nan), jnp.float32)
        tg = np.array([0, 1, 1, 2, -1, -1], np.int32)
        out = np.asarray(grouped_matmul(x, w, jnp.asarray(gs),
                                        jnp.asarray(tg), "ragged"))
        assert np.all(out[int(gs.sum()):] == 0), \
            "residual rows must be zeros, not last-expert garbage"

    def test_all_impls_agree_and_zero_dead(self):
        rng = np.random.default_rng(1)
        bp, gp, tg, nl, capacity = _build(rng, 12, 2, 3, 4)
        d, f = 16, 24
        x = jnp.asarray(rng.normal(size=(capacity, d)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(3, d, f)) * 0.2, jnp.float32)
        outs = {impl: np.asarray(grouped_matmul(
            x, w, jnp.asarray(gp), jnp.asarray(tg), impl))
            for impl in ("ragged", "scan_tiles", "onehot", "pallas")}
        live = bp >= 0
        for impl, out in outs.items():
            np.testing.assert_allclose(out[live], outs["onehot"][live],
                                       rtol=1e-4, atol=1e-4, err_msg=impl)
            assert np.all(out[np.repeat(tg, 4) < 0] == 0), impl


class TestTrafficAndDmaModel:
    def test_fused_strictly_below_two_pass(self):
        for live, n_tiles in ((1, 1), (1, 4), (3, 4), (8, 8), (0, 2)):
            kw = dict(d=64, fe=96, n_up=2, tile_m=8, n_tiles=n_tiles,
                      live_tiles=live)
            fused = expert_ffn_traffic("fused", **kw)["total"]
            two = expert_ffn_traffic("two_pass", **kw)["total"]
            legacy = expert_ffn_traffic("two_pass_legacy", **kw)["total"]
            assert fused < two <= legacy, (live, n_tiles)
        assert expert_ffn_traffic("fused", d=8, fe=8, n_up=1, tile_m=4,
                                  n_tiles=2, live_tiles=0)["total"] == 0

    def test_dma_count_equals_live_tiles(self):
        cases = [
            np.array([0, 1, 2, -1, -1]),
            np.array([0, 0, 2, 2, 2, -1]),
            np.array([1]),
        ]
        for tg in cases:
            k_up, k_dn = 2, 3
            got = fused_weight_dma_tiles(tg, k_up, k_dn)
            live = tg[tg >= 0]
            stripped = fused_weight_dma_tiles(live, k_up, k_dn)
            # trailing dead tiles contribute zero fetches: they park on
            # the last live tile's already-resident blocks
            assert got["dma_tiles"] == stripped["dma_tiles"]
            assert got["m_tiles"] == got["live_tiles"] == len(live)
            assert got["dma_tiles"] == len(live) * (k_up + k_dn)

    def test_all_dead_grid_still_fetches_parked_block(self):
        """A non-empty all-dead grid has no prior live tile to park on:
        the index maps name group 0's first up/down blocks and the
        pipeline physically prefetches each once.  The marginal-cost
        traffic model stays at zero; the DMA count does not."""
        got = fused_weight_dma_tiles(np.array([-1, -1]), 2, 3)
        assert got == {"dma_tiles": 2, "m_tiles": 1, "live_tiles": 0}
        # longer all-dead grids keep parking on the same block
        got4 = fused_weight_dma_tiles(np.array([-1] * 4), 1, 1)
        assert got4 == {"dma_tiles": 2, "m_tiles": 1, "live_tiles": 0}
        # an empty grid runs no pipeline at all
        empty = fused_weight_dma_tiles(np.array([], np.int64), 2, 3)
        assert empty == {"dma_tiles": 0, "m_tiles": 0, "live_tiles": 0}

    def test_single_k_tile_adjacent_group_reuse(self):
        """k_up == k_dn == 1 and a repeated group: the second tile's
        weight indices repeat the first's -> fewer fetches than
        live * phases (revisit-skip upper bound)."""
        got = fused_weight_dma_tiles(np.array([2, 2, 2]), 1, 1)
        assert got["dma_tiles"] == 2            # one up + one down fetch
        assert got["m_tiles"] == 1


class TestOpsInterpretPerCall:
    def test_env_read_per_call(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert kops._interpret() is True
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        assert kops._interpret() is False
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
        assert kops._interpret() is True

    def test_explicit_override_beats_env(self, monkeypatch):
        """interpret=True must work even with the env var demanding
        compiled mode (no TPU here: compiled mode would fail)."""
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        assert kops._interpret(True) is True
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(2, 8, 8)) * 0.2, jnp.float32)
        tg = jnp.asarray([0, 1], jnp.int32)
        out = np.asarray(kops.grouped_ffn_matmul(x, w, tg,
                                                 interpret=True))
        want = ref.grouped_matmul_ref(np.asarray(x), np.asarray(w),
                                      np.asarray(tg))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@pytest.mark.slow
class TestFusedEngineParity:
    """moe_impl="fused" through the real serving engine must generate
    the SAME tokens as "ragged" — routing is identical (replicated
    router, same algo); only the expert datapath changes.  The serve
    harness is the bench's (one copy to keep in sync)."""

    def _serve(self, impl, algo, use_pallas_route=False):
        from benchmarks.bench_moe_kernels import serve_tokens
        return serve_tokens(impl, algo=algo,
                            use_pallas_route=use_pallas_route)

    @pytest.mark.parametrize("algo", ["metro", "eplb"])
    def test_fused_token_identical_to_ragged(self, algo):
        assert self._serve("fused", algo) == self._serve("ragged", algo)

    def test_pallas_route_token_identical(self):
        """EngineConfig.use_pallas_route moves METRO's Alg. 1 onto the
        scalar-core kernel — routing decisions (and therefore tokens)
        must not change."""
        assert (self._serve("fused", "metro", use_pallas_route=True)
                == self._serve("fused", "metro"))
