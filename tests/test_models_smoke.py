"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting output shapes + finite values.  Exercises every
structural feature of the full configs (pattern period, MoE routing,
SSM, enc-dec, qk-norm, SWA) at toy width."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import build_placement, slots_for_ratio
from repro.models import init_lm, apply_lm, lm_loss, init_cache, build_lm_routing
from repro.sharding.policy import make_dist

VIRT_EP = 4  # virtual EP group emulated on one CPU device


def _setup(name):
    cfg = get_config(name).reduced()
    spd = (slots_for_ratio(cfg.num_experts, VIRT_EP, 1.25)
           if cfg.is_moe else 1)
    dist = make_dist(None, ep_size=VIRT_EP, slots_per_device=spd)
    placement = (build_placement(cfg.num_experts, VIRT_EP, spd)
                 if cfg.is_moe else None)
    key = jax.random.PRNGKey(0)
    re = placement.replica_expert if placement else None
    params = init_lm(cfg, key, dist, replica_expert=re)
    routing = build_lm_routing(cfg, placement) if cfg.is_moe else {}
    return cfg, dist, params, routing


def _batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_frames, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(name):
    cfg, dist, params, routing = _setup(name)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits, _, stats = apply_lm(
        cfg, dist, params, tokens=batch.get("tokens"),
        embeds=batch.get("embeds"), frames=batch.get("frames"),
        routing=routing, mode="train", chunk=16)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), \
        f"{name}: non-finite logits"
    if cfg.is_moe:
        assert float(stats["max_activated"]) >= 1


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_train_step_grads_finite(name):
    cfg, dist, params, routing = _setup(name)
    batch = _batch(cfg, 2, 16)

    def loss_fn(p):
        loss, stats = lm_loss(cfg, dist, p, batch, routing=routing,
                              chunk=16)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), \
        f"{name}: non-finite grads"
    # loss should be near log(V) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
        3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_decode_step(name):
    cfg, dist, params, routing = _setup(name)
    if not cfg.supports_decode:
        pytest.skip("no decode step for this family")
    b, max_len = 2, 64
    cache = init_cache(cfg, dist, b, max_len)
    tokens = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.array([3, 7], jnp.int32)
    logits, new_cache, _ = apply_lm(
        cfg, dist, params, tokens=tokens, pos=pos, cache=cache,
        routing=routing, mode="decode", algo="metro" if cfg.is_moe else "eplb")
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache must have been updated somewhere
    changed = jax.tree.map(
        lambda a, b_: bool((jnp.asarray(a) != jnp.asarray(b_)).any()),
        cache, new_cache)
    assert any(jax.tree.leaves(changed)), f"{name}: cache unchanged"


@pytest.mark.parametrize("name", ["mixtral-8x22b", "qwen2-moe-a2.7b",
                                  "jamba-1.5-large-398b"])
def test_prefill_then_decode_consistency(name):
    """Prefill caches then one decode step == train forward at that
    position (teacher forcing).  f32 compute: bf16 noise can flip top-k
    expert choices (an inherent MoE discontinuity, not a datapath bug),
    so exactness is asserted in f32 where routing is stable."""
    cfg, dist, params, routing = _setup(name)
    b, s = 1, 16
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)
    cache = init_cache(cfg, dist, b, s + 8, dtype=jnp.float32)
    f32 = jnp.float32
    # full forward over s+1 tokens (reference)
    ref_logits, _, _ = apply_lm(cfg, dist, params, tokens=toks,
                                routing=routing, mode="train", chunk=16,
                                compute_dtype=f32)
    # prefill s tokens, then decode token s
    _, cache, _ = apply_lm(cfg, dist, params, tokens=toks[:, :s],
                           cache=cache, routing=routing, mode="prefill",
                           chunk=16, compute_dtype=f32)
    dec_logits, _, _ = apply_lm(
        cfg, dist, params, tokens=toks[:, s:s + 1],
        pos=jnp.array([s], jnp.int32), cache=cache, routing=routing,
        mode="decode", compute_dtype=f32)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(ref_logits[:, s], np.float32), rtol=1e-3, atol=5e-3)
